"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh) we derive three times (seconds/step, per chip):

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = effective_collective_bytes_per_device / link_bw

Sources: ``compiled.cost_analysis()`` (flops, bytes accessed) — this is the
*partitioned per-device* module under SPMD, verified by the 6ND cross-check
— and the optimized HLO text for collective operand sizes.

Effective bytes per collective op (ring algorithm on ICI, n = group size):
    all-reduce        2 * (n-1)/n * operand
    all-gather        (n-1)/n * result          (operand is the shard)
    reduce-scatter    (n-1)/n * operand
    all-to-all        (n-1)/n * operand
    collective-permute        operand

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (3D-torus links; we charge the busiest link).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_ALT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    m = _SHAPE_RE.match(type_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    b = _DTYPE_BYTES.get(dt, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return b * n


def _line_operand_bytes(line: str) -> tuple[int, int]:
    """(operand bytes, result bytes) of a collective HLO line."""
    # result type: left of the op name, after '='
    lhs, _, rhs = line.partition("=")
    result = sum(_shape_bytes(s.group(0)) for s in _SHAPE_RE.finditer(
        rhs.split("(")[0]
    ))
    inner = rhs[rhs.find("(") + 1:]
    depth = 1
    args = ""
    for ch in inner:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        args += ch
    operand = sum(_shape_bytes(s.group(0)) for s in _SHAPE_RE.finditer(args))
    return operand, result


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(len(ids), 1)
    m = _GROUPS_ALT_RE.search(line)
    if m:  # iota groups [num_groups, group_size]
        return max(int(m.group(2)), 1)
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    raw_bytes: dict            # summed operand bytes per kind
    effective_bytes: float     # ring-model bytes that cross links, per device

    def total_raw(self) -> int:
        return sum(self.raw_bytes.values())


def collective_stats(hlo_text: str, default_group: int = 256) -> CollectiveStats:
    counts: dict = {}
    raw: dict = {}
    eff = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        op_b, res_b = _line_operand_bytes(line)
        n = _group_size(line, default_group)
        ring = (n - 1) / max(n, 1)
        counts[kind] = counts.get(kind, 0) + 1
        raw[kind] = raw.get(kind, 0) + op_b
        if kind == "all-reduce":
            eff += 2 * ring * op_b
        elif kind == "all-gather":
            eff += ring * res_b
        elif kind in ("reduce-scatter", "all-to-all"):
            eff += ring * op_b
        else:  # collective-permute
            eff += op_b
    return CollectiveStats(counts=counts, raw_bytes=raw, effective_bytes=eff)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    collective: CollectiveStats
    model_flops: float          # 6ND (train) / 2ND (inference), whole step
    n_chips: int

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound on achievable step time."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_fraction(self) -> float:
        """MODEL_FLOPS / (HLO flops summed over chips)."""
        total = self.flops_per_device * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful flops / (chips * peak * step_time)."""
        denom = self.n_chips * PEAK_FLOPS * self.step_time_s
        return self.model_flops / denom if denom else 0.0

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_counts": self.collective.counts,
            "collective_raw_bytes": self.collective.raw_bytes,
            "collective_effective_bytes": self.collective.effective_bytes,
            "model_flops": self.model_flops,
            "useful_flop_fraction": self.useful_flop_fraction,
            "roofline_fraction": self.roofline_fraction,
            "step_time_bound_s": self.step_time_s,
            "n_chips": self.n_chips,
        }


def analyze_walk(walk, mem_estimate, n_chips: int, model_flops: float) -> Roofline:
    """Roofline from the trip-count-aware HLO walk + analytic memory model."""
    coll = CollectiveStats(
        counts=walk.coll_counts,
        raw_bytes=walk.coll_raw,
        effective_bytes=walk.coll_effective,
    )
    return Roofline(
        compute_s=walk.dot_flops / PEAK_FLOPS,
        memory_s=mem_estimate.traffic_bytes / HBM_BW,
        collective_s=walk.coll_effective / LINK_BW,
        flops_per_device=walk.dot_flops,
        bytes_per_device=mem_estimate.traffic_bytes,
        collective=coll,
        model_flops=model_flops,
        n_chips=n_chips,
    )


def analyze(
    cost: dict, hlo_text: str, n_chips: int, model_flops: float
) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_stats(hlo_text, default_group=n_chips)
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll.effective_bytes / LINK_BW,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective=coll,
        model_flops=model_flops,
        n_chips=n_chips,
    )


def model_flops_for(cfg, shape) -> float:
    """6ND for training, 2ND for inference (N = active params; D = tokens).

    Attention score FLOPs are excluded by convention; the useful-flop ratio
    in the table therefore understates usefulness for long-sequence cells —
    noted where material.
    """
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n * tokens
