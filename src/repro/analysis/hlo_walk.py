"""Trip-count-aware HLO static analysis.

``compiled.cost_analysis()`` counts each while-loop body ONCE, but our step
functions are scans over layers x microbatches x kv-chunks — undercounting
flops and (worse) per-layer collectives by 2-3 orders of magnitude.  This
walker parses the optimized HLO text into its computation graph, extracts
static trip counts from loop conditions, and accumulates:

  * dot flops            (2 x |out| x |contraction| per dot, batched incl.)
  * collective bytes     (operand/result sizes per kind, ring-effective)
  * per-kind collective call counts (trip-weighted)

weighted by the product of enclosing trip counts.  Shapes in the optimized
module are per-device (SPMD), so totals are per-device per step.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "opaque": 0,
}

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?(%?[\w\.\-]+)\s*\(.*->.*\{\s*$")
_SHAPE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_WHILE = re.compile(r"while\(.*?\), condition=(%?[\w\.\-]+), body=(%?[\w\.\-]+)")
_KNOWN_TRIPS = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
# ``true_computation=``/``false_computation=`` are the pred-typed conditional
# form; the index-typed form lists its branches in ``branch_computations={}``
# (parsed separately — a brace-delimited name list, not a single name).
_CALLS = re.compile(
    r"(?:calls|to_apply|true_computation|false_computation)=(%?[\w\.\-]+)"
)
_BRANCH_COMPS = re.compile(r"branch_computations=\{([^}]*)\}")
_FUSION_CALLS = re.compile(r"fusion\(.*?calls=(%?[\w\.\-]+)", re.S)
_COLL = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_DOT = re.compile(r"\bdot\(")
_DEF = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\w+\[[\d,]*\])")
_ARGS_OF = re.compile(r"\((%[\w\.\-]+(?:,\s*%[\w\.\-]+)*)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _dims(dims_str: str) -> list[int]:
    return [int(d) for d in dims_str.split(",") if d]


def _nelems(dims_str: str) -> int:
    n = 1
    for d in _dims(dims_str):
        n *= d
    return n


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.groups()
    return _DTYPE_BYTES.get(dt, 4) * _nelems(dims)


def _split_operands(buf: str) -> list[str]:
    """Split an operand list on top-level commas only: inline-typed operands
    (``f32[32,64]{1,0} %x``) carry commas inside ``[]``/``{}``."""
    out, depth, start = [], 0, 0
    for i, ch in enumerate(buf):
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(buf[start:i])
            start = i + 1
    out.append(buf[start:])
    return [t for t in out if t.strip()]


@dataclasses.dataclass
class WalkTotals:
    dot_flops: float = 0.0
    coll_counts: dict = dataclasses.field(default_factory=dict)
    coll_raw: dict = dataclasses.field(default_factory=dict)
    coll_eff_by_kind: dict = dataclasses.field(default_factory=dict)

    @property
    def coll_effective(self) -> float:
        return sum(self.coll_eff_by_kind.values())

    def add(self, other: "WalkTotals", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult
        for k, v in other.coll_raw.items():
            self.coll_raw[k] = self.coll_raw.get(k, 0) + v * mult
        for k, v in other.coll_eff_by_kind.items():
            self.coll_eff_by_kind[k] = (
                self.coll_eff_by_kind.get(k, 0) + v * mult
            )


class HloWalker:
    def __init__(self, hlo_text: str, default_group: int = 256):
        self.default_group = default_group
        self.comps: dict[str, list[str]] = {}
        self.entry: str | None = None
        cur: list[str] | None = None
        name = None
        self.defs: dict[str, dict[str, str]] = {}
        cur_defs: dict[str, str] | None = None
        for line in hlo_text.splitlines():
            m = _COMP_HEADER.match(line)
            if m and "{" in line:
                name = m.group(2).lstrip("%")
                cur = []
                cur_defs = {}
                self.comps[name] = cur
                self.defs[name] = cur_defs
                if m.group(1):
                    self.entry = name
                continue
            if line.startswith("}"):
                cur = None
                cur_defs = None
                continue
            if cur is not None:
                cur.append(line)
                dm = _DEF.match(line)
                if dm:
                    cur_defs[dm.group(1)] = dm.group(2)
        if self.entry is None and self.comps:
            # fall back: computation named like main
            for k in self.comps:
                if "main" in k:
                    self.entry = k
                    break

    # ------------------------------------------------------------------
    def trip_count(self, cond_name: str) -> int:
        """Static trip count heuristic: max s32 constant in the condition."""
        lines = self.comps.get(cond_name.lstrip("%"), [])
        best = 1
        for ln in lines:
            for c in _CONST_S32.findall(ln):
                best = max(best, int(c))
            # constants may live inside a fused compare computation
            fm = _CALLS.search(ln)
            if fm and "fusion" in ln:
                for ln2 in self.comps.get(fm.group(1).lstrip("%"), []):
                    for c in _CONST_S32.findall(ln2):
                        best = max(best, int(c))
        return best

    def _operand_shapes(self, comp: str, line: str) -> list[str]:
        """Operand type strings of the op call on ``line`` (by name lookup)."""
        i = line.find("(", line.find("=") + 1)
        if i < 0:
            return []
        depth, buf = 1, ""
        for ch in line[i + 1:]:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            buf += ch
        defs = self.defs.get(comp, {})
        out = []
        for tok in _split_operands(buf):
            tok = tok.strip()
            # inline-typed operand (unscheduled HLO): f32[8,16] %x
            ms = _SHAPE.match(tok)
            if ms:
                out.append(ms.group(0))
                continue
            if tok.startswith("%") and tok.lstrip("%") in defs:
                out.append(defs[tok.lstrip("%")])
        return out

    def _dot_flops(self, comp: str, line: str) -> float:
        rm = _SHAPE.search(line.split("=", 1)[1] if "=" in line else line)
        if not rm:
            return 0.0
        out_elems = _nelems(rm.group(2))
        ops = self._operand_shapes(comp, line)
        if not ops:
            return 0.0
        lhs = _SHAPE.match(ops[0])
        lhs_dims = _dims(lhs.group(2)) if lhs else []
        cm = _CONTRACT.search(line)
        contract = 1
        if cm:
            for idx in _dims(cm.group(1)):
                if idx < len(lhs_dims):
                    contract *= lhs_dims[idx]
        return 2.0 * out_elems * contract

    def _coll(self, comp: str, line: str, kind: str, tot: WalkTotals):
        lhs, _, rhs = line.partition("=")
        head = rhs.split("(")[0]
        res_b = sum(_shape_bytes(s) for s in _SHAPE.finditer(head))
        ops = self._operand_shapes(comp, line)
        op_b = 0
        for o in ops:
            m = _SHAPE.match(o)
            if m:
                op_b += _shape_bytes(m)
        if op_b == 0:
            op_b = res_b  # same-shape fallback (all-reduce/permute)
        gm = _GROUPS.search(line)
        if gm:
            ids = [x for x in gm.group(1).split(",") if x.strip()]
            n = max(len(ids), 1)
        else:
            gm = _GROUPS_IOTA.search(line)
            n = int(gm.group(2)) if gm else self.default_group
        ring = (n - 1) / max(n, 1)
        tot.coll_counts[kind] = tot.coll_counts.get(kind, 0) + 1
        tot.coll_raw[kind] = tot.coll_raw.get(kind, 0) + op_b
        if kind == "all-reduce":
            eff = 2 * ring * op_b
        elif kind == "all-gather":
            eff = ring * res_b
        elif kind in ("reduce-scatter", "all-to-all"):
            eff = ring * op_b
        else:
            eff = op_b
        tot.coll_eff_by_kind[kind] = tot.coll_eff_by_kind.get(kind, 0) + eff

    # ------------------------------------------------------------------
    def totals_for(self, comp: str, _memo: dict | None = None) -> WalkTotals:
        memo = _memo if _memo is not None else {}
        comp = comp.lstrip("%")
        if comp in memo:
            return memo[comp]
        tot = WalkTotals()
        memo[comp] = tot  # pre-insert (cycles shouldn't occur)
        for line in self.comps.get(comp, []):
            wm = _WHILE.search(line)
            if wm:
                cond, body = wm.groups()
                km = _KNOWN_TRIPS.search(line)
                trips = int(km.group(1)) if km else self.trip_count(cond)
                tot.add(self.totals_for(body, memo), trips)
                tot.add(self.totals_for(cond, memo), trips)
                continue
            cm = _COLL.search(line)
            if cm:
                self._coll(comp, line, cm.group(1), tot)
                continue
            if _DOT.search(line):
                tot.dot_flops += self._dot_flops(comp, line)
            for sub in _CALLS.findall(line):
                tot.add(self.totals_for(sub, memo), 1.0)
            # conditional branch bodies: every branch walked at weight 1 (a
            # conservative upper bound — exactly one executes per visit), so
            # dots/collectives inside a cond are trip-weighted by enclosing
            # loops instead of silently skipped
            for bm in _BRANCH_COMPS.finditer(line):
                for name in bm.group(1).split(","):
                    name = name.strip()
                    if name:
                        tot.add(self.totals_for(name, memo), 1.0)
        return tot

    def walk(self) -> WalkTotals:
        if not self.entry:
            return WalkTotals()
        return self.totals_for(self.entry, {})


def analyze_hlo(hlo_text: str, default_group: int = 256) -> WalkTotals:
    return HloWalker(hlo_text, default_group).walk()
