"""simlint — a static verifier of the engine's structural invariants.

Every performance and correctness claim the engine makes rests on
*structural* properties of the compiled program that ordinary tests cannot
see: the batch-major win requires the phase predicates to lower to real HLO
``conditional``s (not ``select``), campaign donation must actually produce
input/output aliasing, the trace/history bitwise-equality contract requires
instruments to be effect-free observers, and the one-compiled-program
property requires policy knobs to stay traced.  A silent XLA lowering change
or an accidental ``io_callback`` would regress any of them without a test
failing — the numbers would still be right, just slower or un-sweepable.

simlint turns those implicit invariants into machine-checked ones: it traces
the engine's entry points (``simulate`` / ``simulate_trace`` /
``simulate_history``, the batch-major path, ``run_campaign`` chunks, and the
Pallas advance kernel in interpret mode) to jaxpr and optimized HLO, then
runs a registry of rules, each emitting structured ``Finding``s.

Rules (DESIGN.md §11):

=====  ==================  =====================================================
R1     cond-not-select     the provision/dispatch phase predicates survive as
                           ``conditional`` ops with branch computations in the
                           optimized HLO of both engine paths (DESIGN.md §10)
R2     donation-aliases    the campaign chunk runner's compiled module aliases
                           every ``_donate_mask``-donatable input to an output
                           — on the local chunk AND through the shard_map
                           lowering (DESIGN.md §6; the PR-2 never-aliased
                           regression)
R3     pure-observer       driver jaxprs and every Instrument hook carry no
                           effects — no ``io_callback``/``debug_callback``/
                           ``pure_callback``/``debug.print`` (DESIGN.md §3)
R4     shape-stable-scan   no dynamic-shape ops or data-dependent slice widths
                           anywhere in the traced program; ``[B]``-leaf
                           structure is rank-consistent between the single and
                           batch paths (DESIGN.md §10)
R5     recompile-hazard    tracing the same entry across two scenario
                           constructions hits the jit cache — one compilation
                           — and a successive-halving run's rungs all re-enter
                           one compiled streaming-fold program (the
                           one-compiled-program property, DESIGN.md §5/§12)
R6     kernel-budget       the fused advance kernel's launch plan respects the
                           ``ops.advance_block`` heuristic bounds and declares
                           its ``[B]`` SMEM operands scalar-per-row
=====  ==================  =====================================================

The rule bodies are thin wrappers over pure ``check_*`` functions operating
on artifacts (HLO text, jaxprs, kernel plans), so tests can feed adversarial
programs — a vmapped (select-lowered) cond, an undonated runner, a noisy
instrument — and prove each rule fires (tests/test_simlint.py).

CLI: ``scripts/simlint.py`` (human-readable report, ``--json`` for CI,
``--rule``/``--entry`` filters, nonzero exit on error-severity findings).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Iterable

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# findings + rule registry
# ---------------------------------------------------------------------------

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass
class Finding:
    """One structured lint result."""

    rule: str          # "R1" ... "R6"
    name: str          # rule slug, e.g. "cond-not-select"
    severity: str      # "error" | "warning" | "info"
    entry_point: str   # entry (or "instrument:<name>.<hook>") it was found in
    message: str       # what is wrong (or noteworthy)
    evidence: str = ""  # HLO/jaxpr excerpt backing the finding

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Rule:
    rule: str
    name: str
    entries: tuple     # entry points this rule reads (for --entry filtering)
    fn: Callable       # fn(ctx) -> list[Finding]
    doc: str


RULES: dict[str, Rule] = {}


def rule(rule_id: str, name: str, entries: tuple):
    def deco(fn):
        RULES[rule_id] = Rule(
            rule=rule_id, name=name, entries=entries, fn=fn,
            doc=(fn.__doc__ or "").strip().split("\n")[0],
        )
        return fn
    return deco


def _finding(rule_id: str, severity: str, entry: str, message: str,
             evidence: str = "") -> Finding:
    spec = RULES[rule_id]
    return Finding(rule=rule_id, name=spec.name, severity=severity,
                   entry_point=entry, message=message,
                   evidence=evidence.strip()[:500])


# ---------------------------------------------------------------------------
# the lint context: entry points traced lazily, artifacts cached
# ---------------------------------------------------------------------------

# Entry points traced by the default lint run.  ``batch`` is ``simulate`` on
# a stacked campaign (the batch-major step loop); ``campaign_chunk`` is the
# donating chunk runner's compiled module; ``campaign_sharded`` is the same
# chunk lowered through the ``shard_map`` runner on a 1-device ``data`` mesh
# (the sharded-campaign path of DESIGN.md §12 — R1/R2 re-verify that phase
# conditionals and buffer aliasing survive the shard_map lowering, and R5
# probes that successive-halving rungs re-enter one compiled fold program);
# ``advance_pallas`` is the fused advance kernel in interpret mode.
ENTRY_NAMES = (
    "simulate",
    "simulate_trace",
    "simulate_history",
    "batch",
    "campaign_chunk",
    "campaign_sharded",
    "advance_pallas",
)

_BATCH = 4          # rows in the stacked-campaign entry
_TRACE_SAMPLES = 4  # sample points for the simulate_trace entry


class LintContext:
    """Lazily builds and caches the traced/compiled artifacts rules read.

    Tracing and compiling the engine is the expensive part of a lint run, so
    every artifact is computed at most once; ``entries`` restricts which
    entry points may be traced at all (the ``--entry`` CLI filter).
    """

    def __init__(self, entries: Iterable[str] | None = None):
        self.allowed = tuple(entries) if entries else ENTRY_NAMES
        unknown = set(self.allowed) - set(ENTRY_NAMES)
        if unknown:
            raise ValueError(
                f"unknown entry point(s) {sorted(unknown)}; "
                f"known: {list(ENTRY_NAMES)}"
            )
        self._cache: dict = {}

    def wants(self, entry: str) -> bool:
        return entry in self.allowed

    # -- scenarios ---------------------------------------------------------
    @staticmethod
    def _with_topology(scn):
        """Attach a 1-DC uniform topology so the transfer phase
        (step.SCOPE_TRANSFER) exists in every linted program — all lint
        scenarios carry it, keeping R5's structure-identity probe intact."""
        import dataclasses

        from repro.core.energy import Topology
        return dataclasses.replace(scn, topology=Topology.uniform(1))

    def scenario(self, **kw):
        """The canonical single-scenario lint subject (paper Figure 4)."""
        from repro.core import scenarios
        from repro.core.entities import SPACE_SHARED
        key = ("scn", tuple(sorted(kw.items())))
        if key not in self._cache:
            base = self._with_topology(
                scenarios.fig4_scenario(SPACE_SHARED, SPACE_SHARED))
            self._cache[key] = base.replace(**kw) if kw else base
        return self._cache[key]

    def scenario_variant(self):
        """Same shapes/statics as ``scenario()``, different traced values —
        the R5 cache-hit probe."""
        from repro.core import scenarios
        from repro.core.entities import TIME_SHARED
        if "scn_variant" not in self._cache:
            self._cache["scn_variant"] = self._with_topology(
                scenarios.fig4_scenario(
                    TIME_SHARED, TIME_SHARED, length_mi=1000.0))
        return self._cache["scn_variant"]

    def batch_scenario(self):
        """A small stacked campaign (batch-major path)."""
        from repro.core import campaign, scenarios
        from repro.core.entities import SPACE_SHARED
        if "scn_batch" not in self._cache:
            rows = [
                self._with_topology(scenarios.fig4_scenario(
                    SPACE_SHARED, SPACE_SHARED, length_mi=float(m)
                ))
                for m in (1000.0, 2000.0, 3000.0, 4000.0)[:_BATCH]
            ]
            self._cache["scn_batch"] = campaign.stack_scenarios(rows)
        return self._cache["scn_batch"]

    def mesh(self):
        """A 1-device ``data`` mesh: exercises the full shard_map lowering
        (partitioned module, pspec plumbing, donation-through-shards) while
        staying runnable on any host."""
        if "mesh" not in self._cache:
            from jax.sharding import Mesh
            self._cache["mesh"] = Mesh(jax.devices()[:1], ("data",))
        return self._cache["mesh"]

    # -- entry callables ---------------------------------------------------
    def _entry_fn_args(self, entry: str):
        from repro.core import engine
        from repro.kernels import ops
        if entry == "simulate":
            return engine.simulate, (self.scenario(),)
        if entry == "simulate_trace":
            ts = jnp.linspace(0.0, 400.0, _TRACE_SAMPLES)
            return (lambda scn: engine.simulate_trace(scn, ts),
                    (self.scenario(),))
        if entry == "simulate_history":
            return engine.simulate_history, (self.scenario(),)
        if entry == "batch":
            return engine.simulate, (self.batch_scenario(),)
        if entry == "campaign_sharded":
            from repro.core import campaign
            mesh = self.mesh()
            return (lambda scn: campaign._sharded_simulate(scn, mesh, "data"),
                    (self.batch_scenario(),))
        if entry == "advance_pallas":
            b, c = _BATCH, 96
            args = (
                jnp.ones((b, c), jnp.float32),          # rem
                jnp.ones((b, c), jnp.float32),          # rate
                jnp.ones((b, c), bool),                 # active
                jnp.full((b,), 10.0, jnp.float32),      # bound_dt
            )
            return ops.advance_sweep, args
        raise KeyError(f"no traced callable for entry {entry!r}")

    # -- artifacts ---------------------------------------------------------
    def jaxpr(self, entry: str):
        key = ("jaxpr", entry)
        if key not in self._cache:
            fn, args = self._entry_fn_args(entry)
            self._cache[key] = jax.make_jaxpr(fn)(*args)
        return self._cache[key]

    def hlo(self, entry: str) -> str:
        """Optimized (post-XLA) HLO text of the compiled entry."""
        key = ("hlo", entry)
        if key not in self._cache:
            if entry in ("campaign_chunk", "campaign_sharded"):
                from repro.core import campaign
                mesh = self.mesh() if entry == "campaign_sharded" else None
                txt, n_donated = campaign.lower_chunk(
                    self.batch_scenario(), mesh=mesh
                )
                self._cache[key] = txt
                self._cache[("n_donated", entry)] = n_donated
            else:
                fn, args = self._entry_fn_args(entry)
                self._cache[key] = (
                    jax.jit(fn).lower(*args).compile().as_text()
                )
        return self._cache[key]

    def n_donated(self, entry: str = "campaign_chunk") -> int:
        self.hlo(entry)
        return self._cache[("n_donated", entry)]


# ---------------------------------------------------------------------------
# pure checkers (the testable cores)
# ---------------------------------------------------------------------------

_OP_NAME = re.compile(r'op_name="([^"]*)"')
_CONDITIONAL = re.compile(r"=\s*[^=]*\bconditional\(")
_SELECT = re.compile(r"\bselect(?:-and-scatter)?\(|\bselect\b")
_ALIAS_ENTRY = re.compile(r"\{[\d,\s]*\}:\s*\((\d+),")


def _alias_table(header: str) -> str | None:
    """The brace-balanced body of ``input_output_alias={...}`` in an HLO
    module header, or None if the module declares no aliasing."""
    tag = "input_output_alias={"
    start = header.find(tag)
    if start < 0:
        return None
    i, depth = start + len(tag), 1
    for j in range(i, len(header)):
        if header[j] == "{":
            depth += 1
        elif header[j] == "}":
            depth -= 1
            if depth == 0:
                return header[i:j]
    return None


def _scoped_lines(hlo_text: str, scope: str) -> list[str]:
    # a named_scope shows up in op_name as a path component —
    # ".../phase_provision/cond" normally, "vmap(phase_provision)/..." when
    # a vmap swallowed it (the very degradation R1 reports)
    pat = re.compile(rf"(?:^|/|\(){re.escape(scope)}(?:$|/|\))")
    out = []
    for line in hlo_text.splitlines():
        m = _OP_NAME.search(line)
        if m and pat.search(m.group(1)):
            out.append(line.strip())
    return out


def check_cond_not_select(
    hlo_text: str, scopes: Iterable[str], entry: str, rule_id: str = "R1"
) -> list[Finding]:
    """Each phase scope must appear on a ``conditional`` op (with branch
    computations) in the optimized HLO; a scope present only on ``select``
    ops — or absent entirely — means XLA flattened the predicate and both
    branches execute at every event."""
    findings = []
    for scope in scopes:
        lines = _scoped_lines(hlo_text, scope)
        conds = [
            ln for ln in lines
            if _CONDITIONAL.search(ln)
            and ("branch_computations=" in ln or "true_computation=" in ln)
        ]
        if conds:
            continue
        selects = [ln for ln in lines if "select" in ln]
        if selects:
            findings.append(_finding(
                rule_id, "error", entry,
                f"phase predicate scope {scope!r} was flattened to select "
                "(both branches execute at every event; the batch-major "
                "phase-skip win is gone)",
                selects[0],
            ))
        elif not lines:
            findings.append(_finding(
                rule_id, "error", entry,
                f"phase predicate scope {scope!r} not found in the "
                "optimized HLO — the cond was renamed, restructured, or "
                "optimized away entirely",
            ))
        else:
            findings.append(_finding(
                rule_id, "error", entry,
                f"phase predicate scope {scope!r} present but on no "
                "conditional op — lowering changed shape",
                lines[0],
            ))
    return findings


def check_donation_aliases(
    hlo_text: str, n_donated: int, entry: str, rule_id: str = "R2"
) -> list[Finding]:
    """The compiled module's ``input_output_alias`` table must cover the
    donated parameters.  Zero coverage is the PR-2 regression class (an
    error); partial coverage is a warning — an unaliased donated leaf whose
    matching output was constant-folded (e.g. ``downtime`` in a no-outage
    scenario) is benign but worth surfacing."""
    header = hlo_text.splitlines()[0] if hlo_text else ""
    table = _alias_table(header)
    aliased = (
        sorted({int(a) for a in _ALIAS_ENTRY.findall(table)})
        if table else []
    )
    if n_donated <= 0:
        return [_finding(
            rule_id, "error", entry,
            "no donatable leaves at all — _donate_mask matched nothing "
            "against the result avals",
        )]
    if not aliased:
        return [_finding(
            rule_id, "error", entry,
            f"0 of {n_donated} donatable leaves are aliased: buffer "
            "donation is a no-op and chunked campaigns pay double memory",
            header[:300],
        )]
    missing = [i for i in range(n_donated) if i not in aliased]
    if missing:
        return [_finding(
            rule_id, "warning", entry,
            f"{len(missing)} of {n_donated} donatable leaves not aliased "
            f"(donated arg indices {missing}); usually a constant-folded "
            "output, but check after touching SimResult/_donate_mask",
            header[:300],
        )]
    return []


_CALLBACK_PRIMS = (
    "io_callback", "pure_callback", "debug_callback", "debug_print",
)


def _walk_jaxpr_eqns(jaxpr):
    """Yield every eqn in a (Closed)Jaxpr, recursing into sub-jaxprs."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for x in vs:
                if hasattr(x, "jaxpr") or hasattr(x, "eqns"):
                    yield from _walk_jaxpr_eqns(x)


def check_effects(closed_jaxpr, entry: str, rule_id: str = "R3") -> list[Finding]:
    """A driver/hook jaxpr must carry no effects: any effect (io_callback,
    debug print, ...) breaks the pure-observer contract that makes trace =
    history = plain run bitwise and lets XLA reorder freely."""
    findings = []
    effs = getattr(closed_jaxpr, "effects", None) or ()
    if effs:
        findings.append(_finding(
            rule_id, "error", entry,
            f"jaxpr carries effects {sorted(str(e) for e in effs)} — "
            "instruments must be pure observers (DESIGN.md §3)",
        ))
    for eqn in _walk_jaxpr_eqns(closed_jaxpr):
        if any(eqn.primitive.name.startswith(p) for p in _CALLBACK_PRIMS):
            findings.append(_finding(
                rule_id, "error", entry,
                f"callback primitive {eqn.primitive.name!r} in traced "
                "program",
                str(eqn)[:300],
            ))
    return findings


def check_shape_stability(closed_jaxpr, entry: str,
                          rule_id: str = "R4") -> list[Finding]:
    """Every intermediate must have a fully concrete shape, and every
    ``dynamic_slice``-family op must use static slice sizes: a data-dependent
    width would fork the compiled program per trajectory."""
    findings = []
    for eqn in _walk_jaxpr_eqns(closed_jaxpr):
        for var in eqn.outvars:
            shape = getattr(var.aval, "shape", ())
            if not all(isinstance(d, int) for d in shape):
                findings.append(_finding(
                    rule_id, "error", entry,
                    f"non-concrete output shape {shape} from "
                    f"{eqn.primitive.name}",
                    str(eqn)[:300],
                ))
        if eqn.primitive.name in ("dynamic_slice", "dynamic_update_slice"):
            sizes = eqn.params.get("slice_sizes", ())
            if not all(isinstance(s, int) for s in sizes):
                findings.append(_finding(
                    rule_id, "error", entry,
                    f"data-dependent slice widths {sizes} in "
                    f"{eqn.primitive.name}",
                    str(eqn)[:300],
                ))
    return findings


def check_rank_consistency(single_shapes: dict, batch_shapes: dict,
                           batch: int, entry: str,
                           rule_id: str = "R4") -> list[Finding]:
    """Each batch-path SimState leaf must be exactly ``[B] + single`` — the
    contract that lets ``_freeze`` broadcast its row mask per leaf."""
    findings = []
    for path, s_shape in single_shapes.items():
        b_shape = batch_shapes.get(path)
        if b_shape is None:
            findings.append(_finding(
                rule_id, "error", entry,
                f"state leaf {path} exists on the single path only",
            ))
        elif tuple(b_shape) != (batch,) + tuple(s_shape):
            findings.append(_finding(
                rule_id, "error", entry,
                f"state leaf {path}: batch shape {tuple(b_shape)} != "
                f"({batch},) + single shape {tuple(s_shape)}",
            ))
    for path in batch_shapes:
        if path not in single_shapes:
            findings.append(_finding(
                rule_id, "error", entry,
                f"state leaf {path} exists on the batch path only",
            ))
    return findings


def check_one_compilation(jitted, n_calls_expected: int, entry: str,
                          rule_id: str = "R5") -> list[Finding]:
    """After calling a jitted entry on same-shape/same-static inputs, the jit
    cache must hold exactly one executable."""
    size_fn = getattr(jitted, "_cache_size", None)
    if size_fn is None:
        return [_finding(
            rule_id, "info", entry,
            "jit cache size is not inspectable on this jax version; "
            "recompile hazard not checked",
        )]
    n = size_fn()
    if n != 1:
        return [_finding(
            rule_id, "error", entry,
            f"{n} compilations for {n_calls_expected} same-shape calls — "
            "a traced value became static (policy knob? instrument field?) "
            "and forked the jit cache (one-compiled-program property, "
            "DESIGN.md §5)",
        )]
    return []


def check_rung_reuse(n_new_first: int, n_new_repeat: int, entry: str,
                     rule_id: str = "R5") -> list[Finding]:
    """Audit jit-cache *deltas* around a successive-halving run: the first
    run may add at most one executable (every rung — shrinking populations,
    changing fidelities — re-enters the same compiled fold program), and a
    repeat run with different knob values must add none.  Deltas rather than
    absolute sizes because the fold runner is a module-level jit whose cache
    is shared with every other campaign in the process."""
    findings = []
    if n_new_first > 1:
        findings.append(_finding(
            rule_id, "error", entry,
            f"successive-halving compiled {n_new_first} fold programs in "
            "one run — a rung's population/fidelity change forked the jit "
            "cache (fixed-slot ValuesReducer + pinned chunk_size broken?)",
        ))
    if n_new_repeat != 0:
        findings.append(_finding(
            rule_id, "error", entry,
            f"re-running the search with different knob values compiled "
            f"{n_new_repeat} new fold program(s) — a candidate knob became "
            "static (one-compiled-program property, DESIGN.md §5)",
        ))
    return findings


def check_kernel_plan(plan: dict, n_cloudlets: int, max_block: int,
                      entry: str, rule_id: str = "R6") -> list[Finding]:
    """Audit one advance-kernel launch plan against the ``advance_block``
    heuristic bounds and the SMEM scalar-per-row contract."""
    findings = []
    block, b = plan["block"], plan["b"]

    def err(msg, ev=""):
        findings.append(_finding(rule_id, "error", entry, msg, ev))

    if block & (block - 1) or block <= 0:
        err(f"block {block} is not a power of two (C={n_cloudlets})")
    if block < 128:
        err(f"block {block} below the 128-lane floor (C={n_cloudlets})")
    if block > max_block:
        err(f"block {block} above the VMEM cap {max_block} "
            f"(C={n_cloudlets})")
    if n_cloudlets <= max_block and block < n_cloudlets:
        err(f"block {block} splits a row (C={n_cloudlets}) that fits the "
            "cap — the fused single-pass path was forfeited")
    if plan["padded_c"] % block:
        err(f"padded row {plan['padded_c']} not a multiple of block {block}")
    nb = plan["padded_c"] // block
    want_variant = "fused" if nb == 1 else "two_phase"
    if plan["variant"] != want_variant:
        err(f"variant {plan['variant']!r} but nb={nb} implies "
            f"{want_variant!r}")
    want_grid = (b,) if nb == 1 else (b, 2, nb)
    if tuple(plan["grid"]) != want_grid:
        err(f"grid {tuple(plan['grid'])} != expected {want_grid}")
    if tuple(plan["tile"]) != (1, block):
        err(f"tile {tuple(plan['tile'])} != (1, {block}) — more than one "
            "scenario row resident per grid step")
    for kind in ("smem_in", "smem_out"):
        for name, shape in plan[kind]:
            if tuple(shape) != (b,):
                err(f"SMEM operand {name!r} has shape {tuple(shape)}; "
                    f"[B]=({b},) scalars-per-row required")
    if plan["variant"] == "fused" and plan["smem_scratch"]:
        err("fused variant declares SMEM scratch it never reads")
    return findings


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------


@rule("R1", "cond-not-select",
      entries=("simulate", "batch", "campaign_sharded"))
def _rule_cond_not_select(ctx: LintContext) -> list[Finding]:
    """Phase predicates lower to real HLO conditionals, not select."""
    from repro.core import step
    findings = []
    # campaign_sharded re-checks the same property through the shard_map
    # lowering — a partitioner that flattened the conds would silently
    # forfeit phase skipping on every sharded campaign
    for entry in ("simulate", "batch", "campaign_sharded"):
        if not ctx.wants(entry):
            continue
        findings += check_cond_not_select(
            ctx.hlo(entry), step.PHASE_SCOPES, entry
        )
    return findings


@rule("R2", "donation-aliases", entries=("campaign_chunk", "campaign_sharded"))
def _rule_donation_aliases(ctx: LintContext) -> list[Finding]:
    """Campaign chunk donation produces real input/output aliasing."""
    findings = []
    for entry in ("campaign_chunk", "campaign_sharded"):
        if not ctx.wants(entry):
            continue
        findings += check_donation_aliases(
            ctx.hlo(entry), ctx.n_donated(entry), entry
        )
    return findings


def _instrument_hook_jaxprs(scn):
    """(label, ClosedJaxpr) for every hook of every engine instrument,
    including the trace/utilization observers the drivers attach."""
    from repro.core import engine, step

    ts = jnp.linspace(0.0, 400.0, _TRACE_SAMPLES)
    extras = (
        step.TraceInstrument(sample_ts=ts),
        step.UtilizationTimelineInstrument(sample_ts=ts),
    )
    instruments = step.instruments_for(scn, extras)
    st = engine.init_state(scn)
    C, V = scn.cloudlets.n_cloudlets, scn.vms.n_vms
    ev = step.StepEvent(
        t0=jnp.float32(0.0), t1=jnp.float32(1.0), dt=jnp.float32(1.0),
        kind=jnp.int32(0),
        rate=jnp.zeros((C,), jnp.float32),
        active=jnp.zeros((C,), bool),
        rem_before=jnp.zeros((C,), jnp.float32),
        newly_started=jnp.zeros((C,), bool),
        newly_finished=jnp.zeros((C,), bool),
        vm_mips=jnp.zeros((V,), jnp.float32),
    )
    out = []
    for ins in instruments:
        aux = ins.init(scn)
        hooks = {
            "pre": lambda st, aux, ins=ins: ins.pre(scn, st, aux),
            "bound": lambda st, aux, ins=ins: ins.bound(scn, st, aux),
            "post": lambda st, aux, ins=ins: ins.post(scn, st, ev, aux),
            "finalize": lambda st, aux, ins=ins: ins.finalize(scn, st, aux),
        }
        for hook, fn in hooks.items():
            out.append((
                f"instrument:{ins.name}.{hook}",
                jax.make_jaxpr(fn)(st, aux),
            ))
    return out


@rule("R3", "pure-observer",
      entries=("simulate", "simulate_trace", "simulate_history", "batch",
               "campaign_sharded"))
def _rule_pure_observer(ctx: LintContext) -> list[Finding]:
    """Drivers and instrument hooks carry no effects."""
    findings = []
    for entry in ("simulate", "simulate_trace", "simulate_history", "batch",
                  "campaign_sharded"):
        if not ctx.wants(entry):
            continue
        findings += check_effects(ctx.jaxpr(entry), entry)
    if ctx.wants("simulate"):
        for label, cj in _instrument_hook_jaxprs(ctx.scenario()):
            findings += check_effects(cj, label)
    return findings


def _shape_tree(tree) -> dict:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out[jax.tree_util.keystr(path)] = tuple(leaf.shape)
    return out


@rule("R4", "shape-stable-scan",
      entries=("simulate", "batch", "campaign_sharded", "advance_pallas"))
def _rule_shape_stable(ctx: LintContext) -> list[Finding]:
    """All shapes static; SimState rank-consistent across engine paths."""
    from repro.core import engine
    findings = []
    for entry in ("simulate", "batch", "campaign_sharded", "advance_pallas"):
        if not ctx.wants(entry):
            continue
        findings += check_shape_stability(ctx.jaxpr(entry), entry)
    if ctx.wants("batch"):
        scn, scn_b = ctx.scenario(), ctx.batch_scenario()
        single = jax.eval_shape(engine.init_state, scn)
        batch = jax.eval_shape(jax.vmap(engine.init_state), scn_b)
        findings += check_rank_consistency(
            _shape_tree(single), _shape_tree(batch), _BATCH, "batch"
        )
    return findings


@rule("R5", "recompile-hazard",
      entries=("simulate", "batch", "campaign_sharded"))
def _rule_recompile_hazard(ctx: LintContext) -> list[Finding]:
    """Same entry, two scenario constructions, one compilation."""
    from repro.core import engine
    findings = []
    # each probe jits a *fresh* lambda: the pjit tracing cache is keyed on
    # the underlying callable, so two wrappers of engine.simulate itself
    # would pool their entries and double-count
    if ctx.wants("simulate"):
        f = jax.jit(lambda s: engine.simulate(s))
        f(ctx.scenario())
        f(ctx.scenario_variant())
        findings += check_one_compilation(f, 2, "simulate")
    if ctx.wants("batch"):
        from repro.core import campaign
        g = jax.jit(lambda s: engine.simulate(s))
        g(ctx.batch_scenario())
        g(campaign.broadcast_campaign(ctx.scenario_variant(), _BATCH))
        findings += check_one_compilation(g, 2, "batch")
    if ctx.wants("campaign_sharded"):
        # the search driver's rung-reuse claim: a whole successive-halving
        # run (shrinking populations, rising fidelities) through the sharded
        # streaming fold adds at most ONE executable to the fold runner's
        # cache, and a re-run with fresh knob values adds zero.  The fold
        # runner is a module-level jit, so measure deltas, not sizes.
        from repro.core import campaign, search
        size = campaign._run_chunk_fold._cache_size
        space = {"sensor_interval": (1.0, 2.0, 4.0),
                 "ckpt_interval": (50.0, 100.0)}
        kw = dict(n0=4, fidelities=(100.0, 400.0), chunk_size=2,
                  metric="mean_turnaround", mesh=ctx.mesh())
        before = size()
        search.successive_halving(ctx.scenario(), space,
                                  key=jax.random.PRNGKey(0), **kw)
        mid = size()
        search.successive_halving(ctx.scenario(), space,
                                  key=jax.random.PRNGKey(7), **kw)
        findings += check_rung_reuse(
            mid - before, size() - mid, "campaign_sharded"
        )
    return findings


# n_cloudlets probes for R6: around the floor, a mid-size, both sides of the
# pow-2 boundary, and both sides of the VMEM cap (the fallback frontier).
_R6_SIZES = (1, 7, 96, 128, 129, 1000, 4096, 1 << 17, (1 << 17) + 1, 3 << 17)


@rule("R6", "kernel-budget", entries=("advance_pallas",))
def _rule_kernel_budget(ctx: LintContext) -> list[Finding]:
    """Advance-kernel launch plans stay inside the heuristic envelope."""
    from repro.kernels import ops, vm_update
    if not ctx.wants("advance_pallas"):
        return []
    findings = []
    for n in _R6_SIZES:
        block = ops.advance_block(n)
        plan = vm_update.kernel_plan(_BATCH, n, block)
        findings += check_kernel_plan(
            plan, n, ops._MAX_BLOCK, "advance_pallas"
        )
    return findings


# ---------------------------------------------------------------------------
# driver + report
# ---------------------------------------------------------------------------


def run_lint(rules: Iterable[str] | None = None,
             entries: Iterable[str] | None = None) -> list[Finding]:
    """Run the (filtered) rule registry; returns all findings."""
    wanted = tuple(rules) if rules else tuple(RULES)
    unknown = set(wanted) - set(RULES)
    if unknown:
        raise ValueError(
            f"unknown rule(s) {sorted(unknown)}; known: {list(RULES)}"
        )
    ctx = LintContext(entries)
    findings = []
    for rule_id in sorted(wanted):
        findings.extend(RULES[rule_id].fn(ctx))
    order = {s: i for i, s in enumerate(SEVERITIES)}
    findings.sort(key=lambda f: (order.get(f.severity, 99), f.rule))
    return findings


def summarize(findings: list[Finding]) -> dict:
    counts = {s: 0 for s in SEVERITIES}
    for f in findings:
        counts[f.severity] = counts.get(f.severity, 0) + 1
    return counts


def format_report(findings: list[Finding],
                  rules: Iterable[str] | None = None) -> str:
    """Human-readable lint report (the CLI's default output)."""
    lines = []
    checked = sorted(rules) if rules else sorted(RULES)
    for rule_id in checked:
        spec = RULES[rule_id]
        hits = [f for f in findings if f.rule == rule_id]
        status = "ok" if not any(
            f.severity == "error" for f in hits
        ) else "FAIL"
        lines.append(f"[{status:4s}] {rule_id} {spec.name}: {spec.doc}")
        for f in hits:
            lines.append(f"    {f.severity.upper():7s} {f.entry_point}: "
                         f"{f.message}")
            if f.evidence:
                lines.append(f"            | {f.evidence[:160]}")
    counts = summarize(findings)
    lines.append(
        f"simlint: {counts['error']} error(s), {counts['warning']} "
        f"warning(s), {counts['info']} info"
    )
    return "\n".join(lines)
