"""Analytic per-device memory + HBM-traffic model.

The CPU backend's ``memory_analysis()`` reports a no-liveness buffer total
(upper bound) and an arguments-only peak (lower bound), so the HBM-residency
claim and the memory roofline term are derived analytically from the EXACT
sharding layout (param_pspec_tree / input_pspec_tree give the per-leaf shard
fractions) plus a standard activation model:

Residency (train):
    f32 master params + AdamW mu/nu + f32 grad accumulator (4 x params_f32)
    + bf16 weight shard (cast live during compute)
    + remat residuals: one (B_loc, S, D) per layer-period
    + working set ~ 4 activations + logits chunk

Traffic per step (memory roofline term):
    weights   read (2 fwd incl. remat replay + 1 bwd) x microbatches x bf16
    optimizer read+write p/mu/nu f32 (6 x 4 x params)
    residuals write + read
    decode    weights bf16 + full KV/state read (+1/S write)

These match how production TPU memory estimators are built; the dry-run JSON
records them next to XLA's raw numbers.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.dist.sharding import input_pspec_tree, param_pspec_tree, rules_for_mesh


def _shard_frac(spec, mesh) -> float:
    f = 1.0
    for ax in spec:
        if ax is None:
            continue
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            f /= mesh.shape[a]
    return f


def sharded_bytes(shape_tree, spec_tree, mesh, dtype_bytes=None) -> float:
    total = 0.0
    leaves = jax.tree.leaves(shape_tree)
    specs = jax.tree.leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )
    for leaf, spec in zip(leaves, specs):
        n = float(np.prod(leaf.shape)) if leaf.shape else 1.0
        b = dtype_bytes if dtype_bytes is not None else leaf.dtype.itemsize
        total += n * b * _shard_frac(spec, mesh)
    return total


@dataclasses.dataclass
class MemoryEstimate:
    residency_bytes: float
    traffic_bytes: float
    detail: dict

    def as_dict(self):
        return {
            "residency_bytes": self.residency_bytes,
            "traffic_bytes": self.traffic_bytes,
            **{f"detail_{k}": v for k, v in self.detail.items()},
        }


def estimate(model, cfg, shape, mesh, microbatches: int = 1,
             sequence_parallel: bool = False,
             master_bf16: bool = False,
             moments_bf16: bool = False,
             strategy: str = "2d") -> MemoryEstimate:
    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_pspec_tree(pshapes, mesh, strategy)
    p_f32 = sharded_bytes(pshapes, pspecs, mesh, 4)
    p_bf16 = sharded_bytes(pshapes, pspecs, mesh, 2)
    p_master = p_bf16 if master_bf16 else p_f32

    rules = rules_for_mesh(mesh, strategy)
    batch_axes = [a for a in rules.batch if a in mesh.axis_names]
    dp = int(np.prod([mesh.shape[a] for a in batch_axes]))
    tp = mesh.shape.get("model", 1) if rules.tp else 1

    D = cfg.d_model
    act_dt = 2 if cfg.dtype == "bfloat16" else 4
    S = shape.seq_len

    if shape.kind == "train":
        b_loc = max(shape.global_batch // dp, 1) // max(microbatches, 1)
        b_loc = max(b_loc, 1)
        act = b_loc * S * D * act_dt
        layers = cfg.n_layers + (cfg.encoder.n_layers if cfg.encoder else 0)
        sp_div = tp if sequence_parallel else 1
        residuals = layers * act // sp_div
        # working set during one period's recompute: x, qkv/ssm proj, mlp
        # hidden (F/tp), flash accumulators (f32)
        width = max(
            cfg.d_ff // max(tp, 1) if cfg.d_ff else 0,
            (cfg.moe.d_ff if cfg.moe else 0),
            cfg.n_heads * cfg.d_head // max(tp, 1) * 2,
            D,
        )
        working = 4 * b_loc * S * width * act_dt + 2 * b_loc * S * D * 4
        logits_chunk = b_loc * 512 * max(cfg.vocab // tp, 1) * 4
        grads = (4 * p_f32 / 4) if microbatches > 1 else p_master  # f32 acc
        compute_copy = 0 if master_bf16 else p_bf16
        p_moments = 2 * (p_bf16 if moments_bf16 else p_f32)
        residency = (
            p_master + p_moments + grads + compute_copy
            + residuals + working + logits_chunk
        )
        traffic = (
            (2 * microbatches + 1) * p_bf16   # fwd + bwd + remat replay reads
            + 4 * p_f32 + 2 * p_master        # adam r/w moments + master
            + 3 * residuals * microbatches    # write + 2 reads per mb sweep
            + 4 * microbatches * act * 8      # working-set streaming (approx)
        )
        detail = dict(params_f32=p_f32, params_bf16=p_bf16,
                      params_master=p_master,
                      residuals=residuals, working=working,
                      logits_chunk=logits_chunk, local_microbatch=b_loc)
    elif shape.kind == "prefill":
        b_loc = max(shape.global_batch // dp, 1)
        act = b_loc * S * D * act_dt
        layers = cfg.n_layers + (cfg.encoder.n_layers if cfg.encoder else 0)
        cache_shapes = jax.eval_shape(
            lambda: model.init_caches(shape.global_batch, S)
        )
        cache_specs = input_pspec_tree({"caches": cache_shapes}, mesh,
                                       strategy)
        kv = sharded_bytes(cache_shapes, cache_specs["caches"], mesh)
        residency = p_bf16 + kv + 6 * act
        traffic = p_bf16 + kv + 4 * layers * act
        detail = dict(params_bf16=p_bf16, kv_cache=kv, act=act)
    else:  # decode
        cache_shapes = jax.eval_shape(
            lambda: model.init_caches(shape.global_batch, S)
        )
        cache_specs = input_pspec_tree({"caches": cache_shapes}, mesh,
                                       strategy)
        kv = sharded_bytes(cache_shapes, cache_specs["caches"], mesh)
        residency = p_bf16 + kv
        traffic = p_bf16 + kv  # read everything once per token
        detail = dict(params_bf16=p_bf16, kv_cache=kv)

    return MemoryEstimate(residency, traffic, detail)
