"""repro.analysis — static analysis of compiled dry-run artifacts: roofline
extraction (``roofline``) and the structural-invariant linter (``simlint``)."""
from repro.analysis import roofline

__all__ = ["roofline", "simlint"]


def __getattr__(name):
    # simlint imports jax at module load; keep it lazy so lightweight
    # roofline-only consumers don't pay for it
    if name == "simlint":
        import importlib
        return importlib.import_module("repro.analysis.simlint")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
