"""repro.analysis — roofline extraction from compiled dry-run artifacts."""
from repro.analysis import roofline

__all__ = ["roofline"]
