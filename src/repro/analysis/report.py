"""Render the dry-run JSON cache into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import glob
import json
import os

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "phi3-mini-3.8b", "qwen3-32b", "gemma2-27b", "internlm2-1.8b",
    "jamba-v0.1-52b", "whisper-large-v3", "mamba2-130m",
    "qwen3-moe-235b-a22b", "granite-moe-1b-a400m", "qwen2-vl-72b",
]


def load_cells(results_dir: str = "results/dryrun") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def _fmt_ms(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    return f"{s * 1e3:.1f}ms"


def roofline_table(cells: list[dict], mesh: str) -> str:
    rows = [
        "| arch | shape | compute | memory | collective | bottleneck | "
        "useful-FLOP frac | MFU bound | resid GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    index = {(c["arch"], c["shape"]): c for c in cells
             if c.get("mesh") == mesh}
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            c = index.get((arch, shape))
            if c is None:
                continue
            if "skipped" in c:
                rows.append(f"| {arch} | {shape} | — | — | — | "
                            f"skipped: {c['skipped'][:46]} | — | — | — |")
                continue
            if "error" in c:
                rows.append(f"| {arch} | {shape} | ERROR | | | | | | |")
                continue
            r = c["roofline"]
            rows.append(
                f"| {arch} | {shape} | {_fmt_ms(r['compute_s'])} | "
                f"{_fmt_ms(r['memory_s'])} | {_fmt_ms(r['collective_s'])} | "
                f"{r['bottleneck']} | {r['useful_flop_fraction']:.2f} | "
                f"{100 * r['roofline_fraction']:.1f}% | "
                f"{c['memory_model']['residency_bytes'] / 1e9:.2f} |"
            )
    return "\n".join(rows)


def dryrun_table(cells: list[dict], mesh: str) -> str:
    rows = [
        "| arch | shape | compile | HLO flops/dev | coll eff bytes/dev | "
        "collective mix | params |",
        "|---|---|---|---|---|---|---|",
    ]
    index = {(c["arch"], c["shape"]): c for c in cells
             if c.get("mesh") == mesh}
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            c = index.get((arch, shape))
            if c is None or "skipped" in c or "error" in c:
                continue
            r = c["roofline"]
            mix = ", ".join(
                f"{k}:{int(v)}" for k, v in sorted(
                    r["collective_counts"].items())
            )
            rows.append(
                f"| {arch} | {shape} | {c['compile_s']:.0f}s | "
                f"{r['flops_per_device']:.2e} | "
                f"{r['collective_effective_bytes']:.2e} | {mix} | "
                f"{c['params'] / 1e9:.1f}B |"
            )
    return "\n".join(rows)


if __name__ == "__main__":
    cells = load_cells()
    for mesh in ("single", "multi"):
        n_ok = sum(1 for c in cells if c.get("mesh") == mesh
                   and "roofline" in c)
        n_skip = sum(1 for c in cells if c.get("mesh") == mesh
                     and "skipped" in c)
        n_err = sum(1 for c in cells if c.get("mesh") == mesh
                    and "error" in c)
        print(f"== {mesh}: {n_ok} compiled, {n_skip} skipped, "
              f"{n_err} errors ==")
        print(roofline_table(cells, mesh))
        print()
