"""Jitted public wrappers for the kernel layer.

Routing policy:
  * On CPU (this container) the Pallas kernels run in ``interpret=True`` —
    bit-faithful to the kernel body, executed in Python, used by tests.
  * On TPU (the target) ``interpret=False`` compiles to Mosaic.
  * The models/engine default to the pure-jnp reference implementations
    (ref.py), which XLA fuses well and which lower on any backend; the
    Pallas path is selected via config (``attn_impl="pallas"`` etc.).
"""
from __future__ import annotations

import jax
from jax import Array

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.vm_update import advance_sweep_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def advance_sweep(rem: Array, rate: Array, active: Array, bound_dt: Array):
    """Engine advance sweep — Pallas twin of ref.advance_sweep_ref."""
    return advance_sweep_pallas(
        rem, rate, active, bound_dt, interpret=not _on_tpu()
    )


def resolve_advance(impl: str):
    """The single advance-sweep routing point (core.step.resolve_advance
    defers here): ``"jnp"`` -> the fusable reference, ``"pallas"`` -> the
    two-phase Mosaic kernel (interpret mode off-TPU)."""
    if impl == "pallas":
        return advance_sweep
    if impl == "jnp":
        return ref.advance_sweep_ref
    raise ValueError(
        f"unknown sweep_impl {impl!r}: expected 'jnp' or 'pallas'"
    )


def flash_attention(
    q: Array, k: Array, v: Array, *,
    causal: bool = True, window: int | None = None,
    softcap: float = 0.0, scale: float | None = None,
) -> Array:
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        interpret=not _on_tpu(),
    )


def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk: int = 128) -> Array:
    return ssd_scan_pallas(
        x, dt, A, Bm, Cm, D, chunk=chunk, interpret=not _on_tpu()
    )


# re-exported oracles (also the default production path on CPU)
attention_ref = ref.attention_ref
ssd_ref = ref.ssd_ref
ssd_chunked_ref = ref.ssd_chunked_ref
advance_sweep_ref = ref.advance_sweep_ref
