"""Jitted public wrappers for the kernel layer.

Routing policy:
  * On CPU (this container) the Pallas kernels run in ``interpret=True`` —
    bit-faithful to the kernel body, executed in Python, used by tests.
  * On TPU (the target) ``interpret=False`` compiles to Mosaic.
  * The models/engine default to the pure-jnp reference implementations
    (ref.py), which XLA fuses well and which lower on any backend; the
    Pallas path is selected via config (``attn_impl="pallas"`` etc.).
"""
from __future__ import annotations

import jax
from jax import Array

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.vm_update import advance_sweep_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# Largest single tile the fused advance kernel keeps resident per scenario
# row: 2**17 f32 elements x 4 streams = 2 MB, comfortably inside VMEM.  Rows
# longer than this fall back to the per-row two-phase sub-grid.
_MAX_BLOCK = 1 << 17


def advance_block(n_cloudlets: int) -> int:
    """Tile-size heuristic for the advance kernel: the next power of two
    covering the row (floor 128 — the TPU lane width — so tiny Fig-9/10-scale
    scenarios stop paying full-tile overhead), capped at ``_MAX_BLOCK``.
    Whenever the cap is not hit the whole row fits one tile and the kernel
    takes its fused single-pass path."""
    block = 128
    while block < n_cloudlets and block < _MAX_BLOCK:
        block *= 2
    return block


def advance_sweep(rem: Array, rate: Array, active: Array, bound_dt: Array):
    """Engine advance sweep — Pallas twin of ref.advance_sweep_ref.

    Rank-polymorphic like the reference: ``[C]`` per-scenario rows or
    batch-major ``[B, C]`` blocks (the kernel grids over scenario rows
    either way; rank-1 is the B=1 degenerate case).
    """
    return advance_sweep_pallas(
        rem, rate, active, bound_dt,
        block=advance_block(rem.shape[-1]),
        interpret=not _on_tpu(),
    )


def resolve_advance(impl: str):
    """The single advance-sweep routing point (core.step.resolve_advance
    defers here): ``"jnp"`` -> the fusable reference, ``"pallas"`` -> the
    fused batch-grid Mosaic kernel (interpret mode off-TPU).  Both
    implementations pick batch-major vs per-scenario by input rank."""
    if impl == "pallas":
        return advance_sweep
    if impl == "jnp":
        return ref.advance_sweep_ref
    raise ValueError(
        f"unknown sweep_impl {impl!r}: expected 'jnp' or 'pallas'"
    )


def flash_attention(
    q: Array, k: Array, v: Array, *,
    causal: bool = True, window: int | None = None,
    softcap: float = 0.0, scale: float | None = None,
) -> Array:
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        interpret=not _on_tpu(),
    )


def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk: int = 128) -> Array:
    return ssd_scan_pallas(
        x, dt, A, Bm, Cm, D, chunk=chunk, interpret=not _on_tpu()
    )


# re-exported oracles (also the default production path on CPU)
attention_ref = ref.attention_ref
ssd_ref = ref.ssd_ref
ssd_chunked_ref = ref.ssd_chunked_ref
advance_sweep_ref = ref.advance_sweep_ref
