"""Pallas TPU flash attention (GQA + sliding window + logit softcap + causal).

Online-softmax attention tiled for the TPU memory hierarchy: the grid is
``(B, Hq, Sq/bq, Sk/bk)`` with the key axis innermost (sequential on TPU), so
the running (max, sum, accumulator) state lives in VMEM scratch across key
blocks and each q/k/v tile is fetched HBM->VMEM exactly once.  MXU-aligned
tiles (bq, bk multiples of 128 on the matmul dims) keep the systolic array
fed; the softcap/tanh and masking run on the VPU between the two matmuls.

Covers every attention variant in the assigned architecture pool:
  * GQA             — kv-head index map ``h // group`` (no KV repetition in HBM)
  * sliding window  — gemma2 local layers (mask, plus whole-block skip)
  * logit softcap   — gemma2 (applied pre-mask, as in the reference)
  * encoder (non-causal) — whisper encoder / cross-attention

Oracle: ref.attention_ref; swept over shapes/dtypes in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import Array
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_sc, l_sc, *,
                  scale, causal, window, softcap, sq, sk, bq, bk):
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, _NEG)
        l_sc[...] = jnp.zeros_like(l_sc)

    i = pl.program_id(2)
    row = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (sk - sq)
    col = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = col < sk                       # key padding
    if causal:
        valid &= col <= row
    if window is not None:
        valid &= col > row - window

    # Whole-block skip: with causal/window masking many (i, j) tiles are
    # entirely masked; never issue their matmuls.
    row_lo = i * bq + (sk - sq)
    row_hi = row_lo + bq - 1
    col_lo = j * bk
    live = jnp.asarray(True)
    if causal:
        live &= col_lo <= row_hi
    if window is not None:
        live &= (col_lo + bk - 1) > row_lo - window

    @pl.when(live)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(valid, s, _NEG)

        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        # guard: rows with every key masked so far have m == _NEG and would
        # otherwise turn exp(_NEG - _NEG) into spurious mass
        p = jnp.where(s > _NEG / 2, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_sc[...] = alpha * l_sc[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc[...] = acc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_sc[...] = m_new

    @pl.when(j == nk - 1)
    def _emit():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "bq", "bk",
                     "interpret"),
)
def flash_attention_pallas(
    q: Array,  # [B, Hq, Sq, D]
    k: Array,  # [B, Hk, Sk, D]
    v: Array,  # [B, Hk, Sk, D]
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float = 0.0,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> Array:
    B, Hq, Sq, D = q.shape
    Hk, Sk = k.shape[1], k.shape[2]
    assert Hq % Hk == 0, "GQA requires Hq % Hk == 0"
    group = Hq // Hk
    scale_v = (D ** -0.5) if scale is None else scale

    bq_ = min(bq, max(Sq, 8))
    bk_ = min(bk, max(Sk, 8))
    pq = (-Sq) % bq_
    pk = (-Sk) % bk_
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = (Sq + pq) // bq_
    nk = (Sk + pk) // bk_

    kernel = functools.partial(
        _flash_kernel,
        scale=scale_v, causal=causal, window=window, softcap=softcap,
        sq=Sq, sk=Sk, bq=bq_, bk=bk_,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq_, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk_, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk_, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq + pq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, D), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq, :]
