"""Pallas TPU kernels for the framework's compute hot-spots.

  * vm_update        — the simulator's fused advance sweep (min-reduce +
                       work depletion), two-phase sequential grid.
  * flash_attention  — GQA online-softmax attention with sliding window and
                       logit softcap (covers all assigned attention archs).
  * ssd_scan         — Mamba2 state-space-duality chunked scan with the
                       inter-chunk state carried in VMEM scratch.

Each kernel has a pure-jnp oracle in ref.py; ops.py exposes jitted wrappers
that interpret on CPU and compile to Mosaic on TPU.
"""
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.vm_update import advance_sweep_pallas

__all__ = [
    "ops", "ref",
    "flash_attention_pallas", "ssd_scan_pallas", "advance_sweep_pallas",
]
