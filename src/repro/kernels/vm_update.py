"""Pallas TPU kernel for the simulator's advance sweep (``vm_update``).

The hot loop of the tensorized CloudSim engine is, per event and per
scenario row:

    dt      = min( min_i  rem_i / rate_i  over active i,  bound )
    rem_i  -= rate_i * dt

The batch-major engine (core/step.py) calls this on a ``[B, C]`` block —
one row per live scenario — so the kernel is a **batch grid**: grid step
``b`` (``pl.program_id(0)``) owns scenario row ``b`` with the whole cloudlet
tile resident in VMEM, computes the row's min-reduction AND applies the
depletion in one pass, and emits the row's ``dt`` into an SMEM vector.
Fusing the two phases removes the reduce/re-stream round trip that made the
old two-phase kernel lose to jnp: each element is read exactly once.

Rows longer than one tile fall back to a per-row two-phase sub-grid
``(B, 2, nb)`` (phase 0 min-reduces across the row's ``nb`` tiles into SMEM
scratch, phase 1 re-streams and applies) — same math, one extra pass, only
ever taken when a row exceeds the resolver's tile cap (kernels/ops.py picks
the tile: next-pow2 of the row length, floor 128, capped).

Rank-1 inputs (a single scenario) are the degenerate ``B=1`` batch and
return scalars, so one kernel serves both engine paths.

Adaptation note (DESIGN.md §2): CloudSim walks Java object lists here; the
TPU-native form is this dense masked sweep — entity count scales with VMEM
bandwidth, not scheduler overhead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import Array
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INF = 3.0e38


def kernel_plan(b: int, c: int, block: int) -> dict:
    """Static launch geometry for ``advance_sweep_pallas`` — the single
    source of truth for grid, tile and SMEM declarations.

    ``advance_sweep_pallas`` builds its ``pallas_call`` from this plan, and
    simlint rule R6 audits the same plan (block within the
    ``ops.advance_block`` heuristic bounds, ``[B]`` SMEM operands scalar per
    grid row) without instantiating the kernel — so the audited geometry can
    never drift from the launched one.
    """
    pad = (-c) % block
    nb = (c + pad) // block
    plan = {
        "b": b,
        "c": c,
        "block": block,
        "padded_c": c + pad,
        "nb": nb,
        "variant": "fused" if nb == 1 else "two_phase",
        "grid": (b,) if nb == 1 else (b, 2, nb),
        "tile": (1, block),
        # SMEM-resident [B] vectors: one scalar per grid row (program_id(0))
        "smem_in": (("bound_dt", (b,)),),
        "smem_out": (("dt", (b,)),),
        "smem_scratch": () if nb == 1 else (("min_sc", (1,)),),
    }
    return plan


def _fused_kernel(rem_ref, rate_ref, active_ref, bound_ref,
                  dt_ref, out_ref):
    """One grid step == one scenario row, whole cloudlet tile resident."""
    b = pl.program_id(0)
    rem = rem_ref[...]
    rate = rate_ref[...]
    act = active_ref[...] > 0.5
    per = jnp.where(act & (rate > 0), rem / jnp.maximum(rate, 1e-30), _INF)
    dt = jnp.minimum(jnp.min(per), bound_ref[b])
    out_ref[...] = jnp.where(act, jnp.maximum(rem - rate * dt, 0.0), rem)
    dt_ref[b] = dt


def _tiled_kernel(rem_ref, rate_ref, active_ref, bound_ref,
                  dt_ref, out_ref, min_sc):
    """Fallback for rows longer than one tile: per-row two-phase sweep."""
    b = pl.program_id(0)
    phase = pl.program_id(1)
    j = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when((phase == 0) & (j == 0))
    def _init():
        min_sc[0] = bound_ref[b]

    @pl.when(phase == 0)
    def _reduce():
        rem = rem_ref[...]
        rate = rate_ref[...]
        act = active_ref[...] > 0.5
        per = jnp.where(
            act & (rate > 0), rem / jnp.maximum(rate, 1e-30), _INF
        )
        min_sc[0] = jnp.minimum(min_sc[0], jnp.min(per))

    @pl.when(phase == 1)
    def _apply():
        dt = min_sc[0]
        rem = rem_ref[...]
        rate = rate_ref[...]
        act = active_ref[...] > 0.5
        out_ref[...] = jnp.where(
            act, jnp.maximum(rem - rate * dt, 0.0), rem
        )

        @pl.when(j == nb - 1)
        def _emit():
            dt_ref[b] = dt


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def advance_sweep_pallas(
    rem: Array,
    rate: Array,
    active: Array,
    bound_dt: Array,
    *,
    block: int = 1024,
    interpret: bool = True,
) -> tuple[Array, Array]:
    """Fused min-reduce + depletion.

    Batch-major: rem/rate/active ``[B, C]``, bound_dt ``[B]`` ->
    ``(dt [B], rem' [B, C])``.  Rank-1 ``[C]`` inputs with a scalar bound are
    the ``B=1`` special case and return ``(dt scalar, rem' [C])``.
    """
    squeeze = rem.ndim == 1
    out_dtype = rem.dtype
    if squeeze:
        rem, rate, active = rem[None, :], rate[None, :], active[None, :]
    b, c = rem.shape
    plan = kernel_plan(b, c, block)
    pad = plan["padded_c"] - c
    zpad = ((0, 0), (0, pad))
    remp = jnp.pad(rem.astype(jnp.float32), zpad)
    ratep = jnp.pad(rate.astype(jnp.float32), zpad)
    actp = jnp.pad(active.astype(jnp.float32), zpad)  # pad rows inactive
    bound = jnp.reshape(bound_dt.astype(jnp.float32), (b,))

    out_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),        # dt [B]
    ]
    out_shape = [
        jax.ShapeDtypeStruct(plan["smem_out"][0][1], jnp.float32),
        jax.ShapeDtypeStruct((b, plan["padded_c"]), jnp.float32),
    ]
    if plan["variant"] == "fused":
        # one resident tile per row: single-pass fused kernel
        tile = pl.BlockSpec(plan["tile"], lambda i: (i, 0))
        dt, new_rem = pl.pallas_call(
            _fused_kernel,
            grid=plan["grid"],
            in_specs=[tile, tile, tile,
                      pl.BlockSpec(memory_space=pltpu.SMEM)],
            out_specs=out_specs + [tile],
            out_shape=out_shape,
            interpret=interpret,
        )(remp, ratep, actp, bound)
    else:
        tile = pl.BlockSpec(plan["tile"], lambda i, p, j: (i, j))
        dt, new_rem = pl.pallas_call(
            _tiled_kernel,
            grid=plan["grid"],
            in_specs=[tile, tile, tile,
                      pl.BlockSpec(memory_space=pltpu.SMEM)],
            out_specs=out_specs + [tile],
            out_shape=out_shape,
            scratch_shapes=[
                pltpu.SMEM(shape, jnp.float32)
                for _, shape in plan["smem_scratch"]
            ],
            interpret=interpret,
        )(remp, ratep, actp, bound)
    new_rem = new_rem[:, :c].astype(out_dtype)
    if squeeze:
        return dt[0], new_rem[0]
    return dt, new_rem
