"""Pallas TPU kernel for the simulator's advance sweep (``vm_update``).

The hot loop of the tensorized CloudSim engine is, per event:

    dt      = min( min_i  rem_i / rate_i  over active i,  bound )
    rem_i  -= rate_i * dt

A naive implementation reads ``rem``/``rate`` twice from HBM (once for the
min-reduce, once for the update).  On TPU the grid is executed sequentially,
so we fuse both passes into ONE kernel with a two-phase grid
``(2, num_blocks)``: phase 0 accumulates the global min into SMEM scratch,
phase 1 re-streams the blocks and applies the depletion.  VMEM tiles of
``block`` cloudlets keep the working set on-chip; the only cross-block value
is one f32 scalar in SMEM.

Adaptation note (DESIGN.md §2): CloudSim walks Java object lists here; the
TPU-native form is this dense masked sweep — entity count scales with VMEM
bandwidth, not scheduler overhead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import Array
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1.0e30
_INF = 3.0e38


def _sweep_kernel(rem_ref, rate_ref, active_ref, bound_ref,
                  dt_ref, out_ref, min_sc):
    phase = pl.program_id(0)
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when((phase == 0) & (j == 0))
    def _init():
        min_sc[0] = bound_ref[0]

    @pl.when(phase == 0)
    def _reduce():
        rem = rem_ref[...]
        rate = rate_ref[...]
        act = active_ref[...] > 0.5
        dt_block = jnp.where(
            act & (rate > 0), rem / jnp.maximum(rate, 1e-30), _INF
        )
        min_sc[0] = jnp.minimum(min_sc[0], jnp.min(dt_block))

    @pl.when(phase == 1)
    def _apply():
        dt = min_sc[0]
        rem = rem_ref[...]
        rate = rate_ref[...]
        act = active_ref[...] > 0.5
        out_ref[...] = jnp.where(
            act, jnp.maximum(rem - rate * dt, 0.0), rem
        )

        @pl.when(j == nb - 1)
        def _emit():
            dt_ref[0] = dt


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def advance_sweep_pallas(
    rem: Array,
    rate: Array,
    active: Array,
    bound_dt: Array,
    *,
    block: int = 1024,
    interpret: bool = True,
) -> tuple[Array, Array]:
    """Fused min-reduce + depletion. Shapes: rem/rate/active [C] -> (dt, rem')."""
    (c,) = rem.shape
    pad = (-c) % block
    remp = jnp.pad(rem.astype(jnp.float32), (0, pad))
    ratep = jnp.pad(rate.astype(jnp.float32), (0, pad))
    actp = jnp.pad(active.astype(jnp.float32), (0, pad))  # pad rows inactive
    nb = (c + pad) // block
    bound = jnp.reshape(bound_dt.astype(jnp.float32), (1,))

    dt, new_rem = pl.pallas_call(
        _sweep_kernel,
        grid=(2, nb),
        in_specs=[
            pl.BlockSpec((block,), lambda p, j: (j,)),
            pl.BlockSpec((block,), lambda p, j: (j,)),
            pl.BlockSpec((block,), lambda p, j: (j,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block,), lambda p, j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((c + pad,), jnp.float32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(remp, ratep, actp, bound)
    return dt[0], new_rem[:c].astype(rem.dtype)
