"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the semantic ground truth: each kernel's interpret-mode output is
asserted allclose against these over a shape/dtype sweep (tests/test_kernels).
They are also the *production CPU path*: the engine and the models call these
unless explicitly configured for the Pallas variants (TPU target).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

INF = jnp.float32(3.0e38)


# ---------------------------------------------------------------------------
# advance sweep (the simulator's updateVMsProcessing hot loop)
# ---------------------------------------------------------------------------

def advance_sweep_ref(
    rem: Array, rate: Array, active: Array, bound_dt: Array
) -> tuple[Array, Array]:
    """dt to next completion (capped by ``bound_dt``) + work depletion.

    Rank-polymorphic over a leading scenario axis: ``[C]`` inputs with a
    scalar bound reduce to a scalar ``dt``; batch-major ``[B, C]`` inputs
    with a ``[B]`` bound reduce per row to ``dt [B]`` — bitwise the same
    per-row math as ``vmap`` of the rank-1 form (the batch engine's
    bit-identity contract, DESIGN.md §10).
    """
    dt_fin = jnp.where(active & (rate > 0), rem / jnp.maximum(rate, 1e-30), INF)
    dt = jnp.minimum(jnp.min(dt_fin, axis=-1, initial=INF), bound_dt)
    new_rem = jnp.where(
        active, jnp.maximum(rem - rate * dt[..., None], 0.0), rem
    )
    return dt, new_rem


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _attn_mask(sq: int, sk: int, causal: bool, window: int | None) -> Array:
    """[sq, sk] bool. Rows are aligned to the *end* of the key axis (standard
    decode/prefill alignment: query i attends keys <= i + (sk - sq))."""
    row = jnp.arange(sq)[:, None] + (sk - sq)
    col = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= col <= row
    if window is not None:
        mask &= col > row - window
    return mask


def attention_ref(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float = 0.0,
    scale: float | None = None,
) -> Array:
    """Dense softmax attention with GQA, sliding window and logit softcap.

    q: [B, Hq, Sq, D]; k, v: [B, Hk, Sk, D] with Hq % Hk == 0.
    """
    B, Hq, Sq, D = q.shape
    Hk, Sk = k.shape[1], k.shape[2]
    g = Hq // Hk
    scale = (D ** -0.5) if scale is None else scale
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    s = s * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    mask = _attn_mask(Sq, Sk, causal, window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality) — sequential-scan oracle
# ---------------------------------------------------------------------------

def ssd_ref(
    x: Array,      # [B, S, H, P]
    dt: Array,     # [B, S, H]   (positive step sizes, post-softplus)
    A: Array,      # [H]         (negative decay rates)
    Bm: Array,     # [B, S, G, N]
    Cm: Array,     # [B, S, G, N]
    D: Array,      # [H]         skip connection
) -> Array:
    """y_t = C_t h_t + D x_t with h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T.

    Plain lax.scan over time; the Pallas twin (ssd_scan.py) is chunk-parallel.
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)  # [B,S,H,N]
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # [B,H,P], [B,H], [B,H,N], [B,H,N]
        decay = jnp.exp(dtt * A)[..., None, None]          # [B,H,1,1]
        upd = (dtt[..., None, None] * xt[..., :, None]) * bt[..., None, :]
        h = decay * h + upd                                 # [B,H,P,N]
        yt = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, yt

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bh, 1, 0),
        jnp.moveaxis(Ch, 1, 0),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + D[None, None, :, None] * xf
    return y.astype(x.dtype)


def ssd_chunked_ref(
    x: Array, dt: Array, A: Array, Bm: Array, Cm: Array, D: Array,
    chunk: int = 64, return_state: bool = False,
):
    """Chunk-parallel SSD in pure jnp (the math the Pallas kernel implements;
    also the production CPU/XLA path used by the Mamba2 model for training).
    With ``return_state`` also returns the final [B, H, P, N] SSM state
    (prefill needs it to seed decode).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert S % chunk == 0, "sequence must be chunk-padded"
    nc = S // chunk
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dA = dt.astype(jnp.float32) * A[None, None, :]          # [B,S,H]

    # reshape into chunks: [B, nc, Q, ...]
    xc = xf.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.astype(jnp.float32).reshape(Bsz, nc, chunk, H)
    dAc = dA.reshape(Bsz, nc, chunk, H)
    Bc = Bh.reshape(Bsz, nc, chunk, H, N)
    Cc = Ch.reshape(Bsz, nc, chunk, H, N)

    cum = jnp.cumsum(dAc, axis=2)                            # [B,nc,Q,H]
    seg = cum[:, :, -1, :]                                   # [B,nc,H]

    # intra-chunk (dual quadratic form)
    CB = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc)            # [B,nc,H,Q,Q]
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # [B,nc,Q,K,H]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    W = CB * jnp.moveaxis(L, -1, 2)                          # [B,nc,H,Q,K]
    y_intra = jnp.einsum(
        "bchqk,bckh,bckhp->bcqhp", W, dtc, xc
    )

    # inter-chunk: carry state across chunks with a scan over nc
    w = jnp.exp(seg[:, :, None, :] - cum) * dtc              # [B,nc,Q,H]
    state_in = jnp.einsum("bcqhp,bcqh,bcqhn->bchpn", xc, w, Bc)

    def carry(h, inp):
        s_in, decay = inp                                    # [B,H,P,N], [B,H]
        h_out = h                                            # state BEFORE chunk
        h = decay[..., None, None] * h + s_in
        return h, h_out

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_final, h_prev = jax.lax.scan(
        carry,
        h0,
        (jnp.moveaxis(state_in, 1, 0), jnp.moveaxis(jnp.exp(seg), 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)                      # [B,nc,H,P,N]
    y_inter = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", Cc, h_prev, jnp.exp(cum)
    )
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    y = y + D[None, None, :, None] * xf
    y = y.astype(x.dtype)
    if return_state:
        return y, h_final
    return y
