"""Pallas TPU kernel for the Mamba2 SSD (state-space duality) chunked scan.

The SSD recurrence  h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T,
                    y_t = C_t h_t + D x_t
is evaluated chunk-parallel (arXiv:2405.21060 §6): within a chunk of Q steps
the dual quadratic form (an attention-like [Q, Q] matmul with a decay mask)
produces the intra-chunk output on the MXU, while a [P, N] state matrix in
VMEM scratch carries the recurrence *across* chunks — the chunk axis is the
innermost TPU grid dimension, which executes sequentially, so the carried
state never round-trips to HBM.

Grid: (B, H, S/Q).  Tiles: x (Q, P), B/C (Q, N), dt (Q,) with Q = 128 and
P = N = 64..128 — three MXU-shaped matmuls per chunk ([QxN]@[NxQ],
[QxQ]@[QxP], [QxN]@[NxP]) plus VPU exp/cumsum.

Hardware adaptation (DESIGN.md §2): the original Mamba2 kernel is a CUDA
warp-specialized scan; on TPU the same math maps onto the sequential grid +
VMEM-resident state, with no cross-lane shuffles needed.

Oracle: ref.ssd_ref (sequential scan) and ref.ssd_chunked_ref (same math in
plain jnp, also the production XLA path for training).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import Array
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, d_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state,
                *, chunk: int):
    h = pl.program_id(1)
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _reset():
        state[...] = jnp.zeros_like(state)

    a = a_ref[h]                                   # scalar decay rate (SMEM)
    dskip = d_ref[h]
    x = x_ref[0, 0].astype(jnp.float32)            # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)          # [Q]
    Bm = b_ref[0, 0].astype(jnp.float32)           # [Q, N]
    Cm = c_ref[0, 0].astype(jnp.float32)           # [Q, N]

    dA = dt * a                                    # [Q]
    cum = jnp.cumsum(dA)                           # inclusive
    seg = cum[chunk - 1]

    # intra-chunk dual form: W[i,j] = (C_i . B_j) exp(cum_i - cum_j) dt_j, i>=j
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    w = jnp.where(li >= lj, cb * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q, P]

    # inter-chunk: y_i += C_i h_in exp(cum_i);  h_in = state before this chunk
    y += jax.lax.dot_general(
        Cm, state[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * jnp.exp(cum)[:, None]

    y_ref[0, 0] = (y + dskip * x).astype(y_ref.dtype)

    # state update: h_out = exp(seg) h_in + sum_j exp(seg - cum_j) dt_j x_j B_j^T
    wj = jnp.exp(seg - cum) * dt                                  # [Q]
    state[...] = jnp.exp(seg) * state[...] + jax.lax.dot_general(
        x, Bm * wj[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                              # [P, N]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    x: Array,    # [B, S, H, P]
    dt: Array,   # [B, S, H]
    A: Array,    # [H]
    Bm: Array,   # [B, S, G, N]
    Cm: Array,   # [B, S, G, N]
    D: Array,    # [H]
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> Array:
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert H % G == 0
    rep = H // G
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 -> identity steps
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    # layout: time-major per (b, h) tiles
    xt = jnp.transpose(x, (0, 2, 1, 3))            # [B, H, S, P]
    dtt = jnp.transpose(dt, (0, 2, 1))             # [B, H, S]
    Bt = jnp.transpose(Bm, (0, 2, 1, 3))           # [B, G, S, N]
    Ct = jnp.transpose(Cm, (0, 2, 1, 3))

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # A [H]
            pl.BlockSpec(memory_space=pltpu.SMEM),  # D [H]
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b, h, c, r=rep: (b, h // r, c, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b, h, c, r=rep: (b, h // r, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, H, Sp, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(A.astype(jnp.float32), D.astype(jnp.float32), xt, dtt, Bt, Ct)

    return jnp.transpose(y, (0, 2, 1, 3))[:, :S]
