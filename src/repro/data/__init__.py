"""repro.data — deterministic synthetic pipeline (per-host sharded, prefetched)."""
from repro.data.pipeline import MarkovSource, ShardedLoader

__all__ = ["MarkovSource", "ShardedLoader"]
