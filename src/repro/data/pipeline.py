"""Deterministic synthetic token pipeline with per-host sharding + prefetch.

The workload is a seeded order-1 Markov chain over the vocabulary — learnable
structure (a model that trains will push loss well below ln(vocab)) while
requiring no external data.  ``ShardedLoader`` yields each host its disjoint
slice of the global batch (multi-host data parallelism) and prefetches the
next batch on a background thread so host-side generation overlaps device
compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class MarkovSource:
    """Seeded Markov chain text source; identical stream for a given seed."""

    def __init__(self, vocab: int, seed: int = 0, branching: int = 4):
        self.vocab = vocab
        rng = np.random.default_rng(seed)
        # each token has `branching` likely successors
        self.succ = rng.integers(0, vocab, size=(vocab, branching))
        self.noise = 0.05

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq + 1), np.int32)
        out[:, 0] = rng.integers(0, self.vocab, size=batch)
        for t in range(seq):
            branch = rng.integers(0, self.succ.shape[1], size=batch)
            nxt = self.succ[out[:, t], branch]
            flip = rng.random(batch) < self.noise
            nxt = np.where(flip, rng.integers(0, self.vocab, size=batch), nxt)
            out[:, t + 1] = nxt
        return out


class ShardedLoader:
    """Yields {'tokens','labels'} host-local batches, prefetched."""

    def __init__(self, vocab: int, global_batch: int, seq_len: int,
                 host_id: int = 0, n_hosts: int = 1, seed: int = 0,
                 prefetch: int = 2):
        assert global_batch % n_hosts == 0
        self.local_batch = global_batch // n_hosts
        self.seq = seq_len
        self.src = MarkovSource(vocab, seed)
        self.host_id, self.n_hosts, self.seed = host_id, n_hosts, seed
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = 0
        while not self._stop.is_set():
            # per-(step, host) seed -> deterministic, disjoint across hosts
            rng = np.random.default_rng(
                (self.seed, step, self.host_id)
            )
            full = self.src.sample(rng, self.local_batch, self.seq)
            batch = {"tokens": full[:, :-1], "labels": full[:, 1:].copy()}
            try:
                self._q.put(batch, timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
