"""Mamba2 block (state-space duality): projections, causal conv, SSD core.

Train/prefill: chunk-parallel SSD — ``kernels.ref.ssd_chunked_ref`` on the
XLA path or the Pallas ``ssd_scan`` kernel on TPU.  Decode: O(1) recurrent
update carrying (conv window, SSM state) per layer.

Layout per block (following Mamba2):
  separate projections D -> z (d_inner), x (d_inner), B (G*N), C (G*N), dt (H)
  causal depthwise conv (width w) over the x/B/C channels
  SSD over H heads of head_dim P = d_inner / H
  gated RMSNorm (z branch) -> out_proj: d_inner -> D

Sharding note (EXPERIMENTS.md §Perf, mamba2 x prefill finding): Mamba2's
reference fuses z/x/B/C/dt into ONE in_proj whose output is then sliced.
Under tensor parallelism the slice boundaries (1536/3072/3200/...) don't
align with the model-axis shard boundaries, and GSPMD materializes halo
collective-permutes over the full [B, S, *] activations (~320 GB/step
measured).  Keeping the projections as separate weights makes every tensor
individually shard-aligned — same math, zero permutes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

from repro.dist.act_sharding import shard_act
from repro.models import layers


def _dims(cfg):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    H = s.n_ssm_heads(cfg.d_model)
    return s, di, H


def init_ssm(key, cfg) -> dict:
    s, di, H = _dims(cfg)
    G, N = s.n_groups, s.d_state
    gn = G * N
    ks = jax.random.split(key, 7)
    return {
        "w_z": layers.trunc_normal(ks[0], (cfg.d_model, di)),
        "w_x": layers.trunc_normal(ks[1], (cfg.d_model, di)),
        "w_B": layers.trunc_normal(ks[2], (cfg.d_model, gn)),
        "w_C": layers.trunc_normal(ks[3], (cfg.d_model, gn)),
        "w_dt": layers.trunc_normal(ks[4], (cfg.d_model, H)),
        "conv_w": layers.trunc_normal(ks[5], (s.conv_width, di + 2 * gn),
                                      scale=0.5),
        "conv_b": jnp.zeros((di + 2 * gn,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01, jnp.float32))),
        "norm": layers.init_rms_norm(di),
        "out_proj": layers.trunc_normal(ks[6], (di, cfg.d_model)),
    }


def _project(params: dict, cfg, x: Array):
    """Separate shard-aligned projections -> (z, x, B, C, dt_raw)."""
    dt_ = x.dtype
    z = shard_act(x @ params["w_z"].astype(dt_), ("batch", None, "model"))
    xs = shard_act(x @ params["w_x"].astype(dt_), ("batch", None, "model"))
    bs = shard_act(x @ params["w_B"].astype(dt_), ("batch", None, "model"))
    cs = shard_act(x @ params["w_C"].astype(dt_), ("batch", None, "model"))
    dt_raw = x @ params["w_dt"].astype(dt_)
    return z, xs, bs, cs, dt_raw


def _conv_parts(cfg):
    s, di, H = _dims(cfg)
    gn = s.n_groups * s.d_state
    return di, gn


def _causal_conv_parts(cfg, params, xs, bs, cs):
    """Depthwise causal conv applied per part (weights stored concatenated
    [W, di+2gn]; slicing WEIGHTS is free — they're tiny and replicated on
    the sliced axis boundary-compatible shards)."""
    di, gn = _conv_parts(cfg)
    w, b = params["conv_w"], params["conv_b"]
    xs = _causal_conv(xs, w[:, :di], b[:di])
    bs = _causal_conv(bs, w[:, di:di + gn], b[di:di + gn])
    cs = _causal_conv(cs, w[:, di + gn:], b[di + gn:])
    return xs, bs, cs


def _causal_conv(xbc: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over time. xbc: [B, S, C]; w: [W, C]."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(W):  # W is tiny (4); unrolled taps fuse into one op
        out = out + pad[:, i : i + xbc.shape[1]] * w[i].astype(xbc.dtype)
    return jax.nn.silu(out + b.astype(xbc.dtype))


def ssm_apply(params: dict, cfg, x: Array, *, impl: str = "xla") -> Array:
    """Train/prefill path. x: [B, S, D] -> [B, S, D]."""
    s, di, H = _dims(cfg)
    G, N, P = s.n_groups, s.d_state, s.head_dim
    B, S, D = x.shape
    dt_ = x.dtype

    z, xs, bs, cs, dt_raw = _project(params, cfg, x)
    xs, bs, cs = _causal_conv_parts(cfg, params, xs, bs, cs)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"][None, None]
    )                                                     # [B,S,H]
    A = -jnp.exp(params["A_log"])                         # [H] negative
    xh = xs.reshape(B, S, H, P)
    bh = bs.reshape(B, S, G, N)
    ch = cs.reshape(B, S, G, N)

    if impl == "pallas":
        from repro.kernels import ops

        y = ops.ssd_scan(xh, dt, A, bh, ch, params["D"], chunk=s.chunk)
    else:
        from repro.kernels import ref

        y = ref.ssd_chunked_ref(xh, dt, A, bh, ch, params["D"], chunk=min(s.chunk, S))

    y = y.reshape(B, S, di)
    y = layers.rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return y @ params["out_proj"].astype(dt_)


def ssm_prefill(params: dict, cfg, x: Array):
    """Prefill: outputs + (conv tail window, final SSM state) to seed decode."""
    s, di, H = _dims(cfg)
    G, N, P = s.n_groups, s.d_state, s.head_dim
    B, S, D = x.shape
    dt_ = x.dtype

    z, xs_raw, bs_raw, cs_raw, dt_raw = _project(params, cfg, x)
    xs, bs, cs = _causal_conv_parts(cfg, params, xs_raw, bs_raw, cs_raw)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"][None, None]
    )
    A = -jnp.exp(params["A_log"])
    from repro.kernels import ref

    pad = (-S) % s.chunk
    chunk = min(s.chunk, S + pad)
    if pad:
        xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        bs_p = jnp.pad(bs, ((0, 0), (0, pad), (0, 0)))
        cs_p = jnp.pad(cs, ((0, 0), (0, pad), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 -> identity
    else:
        xs_p, bs_p, cs_p, dt_p = xs, bs, cs, dt
    y, h_final = ref.ssd_chunked_ref(
        xs_p.reshape(B, S + pad, H, P), dt_p,
        A, bs_p.reshape(B, S + pad, G, N), cs_p.reshape(B, S + pad, G, N),
        params["D"], chunk=chunk, return_state=True,
    )
    y = y[:, :S].reshape(B, S, di)
    y = layers.rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(dt_)

    # conv tail: last (W-1) *pre-activation* conv inputs (x|B|C concatenated)
    W = s.conv_width
    xbc_raw = jnp.concatenate([xs_raw, bs_raw, cs_raw], axis=-1)
    tail = jnp.pad(xbc_raw, ((0, 0), (W - 1, 0), (0, 0)))[:, -(W - 1):]
    return out, tail.astype(dt_), h_final


# ---------------------------------------------------------------------------
# decode (recurrent) path
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg, batch: int, n_ssm_layers: int, dtype):
    s, di, H = _dims(cfg)
    G, N, P = s.n_groups, s.d_state, s.head_dim
    conv_dim = di + 2 * G * N
    return {
        "conv": jnp.zeros((n_ssm_layers, batch, s.conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((n_ssm_layers, batch, H, P, N), jnp.float32),
    }


def ssm_decode(params: dict, cfg, x: Array, conv_state: Array, ssm_state: Array):
    """One-token recurrent step.

    x: [B, 1, D]; conv_state: [B, W-1, conv_dim]; ssm_state: [B, H, P, N].
    """
    s, di, H = _dims(cfg)
    G, N, P = s.n_groups, s.d_state, s.head_dim
    gn = G * N
    B = x.shape[0]
    dt_ = x.dtype

    z, xs, bs, cs, dt_raw = _project(params, cfg, x)
    z, xs, bs, cs, dt_raw = (t[:, 0] for t in (z, xs, bs, cs, dt_raw))
    xbc = jnp.concatenate([xs, bs, cs], axis=-1)          # [B, conv_dim]

    # conv: window = (state, new) -> output tap
    win = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # [B,W,C]
    w = params["conv_w"].astype(dt_)                      # [W, C]
    conv_out = jnp.einsum("bwc,wc->bc", win, w) + params["conv_b"].astype(dt_)
    conv_out = jax.nn.silu(conv_out)
    new_conv_state = win[:, 1:]

    xs = conv_out[:, :di]
    bs = conv_out[:, di:di + gn]
    cs = conv_out[:, di + gn:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None])
    A = -jnp.exp(params["A_log"])                         # [H]
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    bh = jnp.repeat(bs.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    ch = jnp.repeat(cs.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)

    decay = jnp.exp(dt * A)[..., None, None]              # [B,H,1,1]
    upd = (dt[..., None, None] * xh[..., None]) * bh[:, :, None, :]
    new_ssm = decay * ssm_state + upd                     # [B,H,P,N]
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, ch)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(B, 1, di).astype(dt_)
    y = layers.rms_norm(y * jax.nn.silu(z[:, None]), params["norm"], cfg.norm_eps)
    return y @ params["out_proj"].astype(dt_), new_conv_state, new_ssm
