"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

The conv frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed frame embeddings [B, n_ctx, D] (whisper-large-v3: 1500 x 1280).
Positional information is learned-absolute (whisper), so attention runs with
rope disabled.  Decoder = causal self-attention + cross-attention over the
encoder output + SwiGLU MLP, scanned over stacked layer params.

Decode path: self-attn KV cache (grown to the assigned decode shapes) plus
per-layer cross K/V computed once at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

from repro.dist.act_sharding import shard_act
from repro.models import attention as attn
from repro.models import layers
from repro.models.config import ModelConfig


def _init_enc_layer(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": layers.init_rms_norm(cfg.d_model),
        "attn": attn.init_attention(k1, cfg),
        "norm2": layers.init_rms_norm(cfg.d_model),
        "mlp": layers.init_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def _init_dec_layer(key, cfg) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": layers.init_rms_norm(cfg.d_model),
        "self_attn": attn.init_attention(k1, cfg),
        "norm_x": layers.init_rms_norm(cfg.d_model),
        "cross_attn": attn.init_attention(k2, cfg, cross=True),
        "norm2": layers.init_rms_norm(cfg.d_model),
        "mlp": layers.init_mlp(k3, cfg.d_model, cfg.d_ff),
    }


def init_encdec(key, cfg: ModelConfig) -> dict:
    ke, kd, kt, kp1, kp2 = jax.random.split(key, 5)
    enc = cfg.encoder
    max_pos = cfg.max_position or 32_768
    return {
        "embed": layers.init_embed(kt, cfg.vocab, cfg.d_model),
        "enc_pos": layers.trunc_normal(kp1, (enc.n_ctx, cfg.d_model), scale=0.01),
        "dec_pos": layers.trunc_normal(kp2, (max_pos, cfg.d_model), scale=0.01),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(
            jax.random.split(ke, enc.n_layers)
        ),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(
            jax.random.split(kd, cfg.n_layers)
        ),
        "enc_final_norm": layers.init_rms_norm(cfg.d_model),
        "final_norm": layers.init_rms_norm(cfg.d_model),
    }


def encode(params: dict, cfg: ModelConfig, frames: Array) -> Array:
    """frames: [B, n_ctx, D] (stub embeddings) -> encoder states."""
    dt = cfg.compute_dtype
    x = frames.astype(dt) + params["enc_pos"][None, : frames.shape[1]].astype(dt)

    def layer(x, lp):
        x = shard_act(x, ("batch", "seq", None))
        h = layers.rms_norm(x, lp["norm1"], cfg.norm_eps)
        h = attn.attention(lp["attn"], cfg, h, causal=False, rope=False)
        x = x + h
        h = layers.rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = shard_act(x + layers.mlp(lp["mlp"], h), ("batch", "seq", None))
        return x, None

    body = jax.checkpoint(layer) if cfg.remat else layer
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return layers.rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def _dec_trunk(params, cfg, tokens, enc_out, positions=None):
    dt = cfg.compute_dtype
    B, S = tokens.shape
    if positions is None:
        positions = jnp.arange(S)
    x = layers.embed(params["embed"], tokens, dt)
    x = x + params["dec_pos"].astype(dt)[positions][None]

    def layer(x, lp):
        x = shard_act(x, ("batch", "seq", None))
        h = layers.rms_norm(x, lp["norm1"], cfg.norm_eps)
        h = attn.attention(lp["self_attn"], cfg, h, causal=True, rope=False)
        x = x + h
        h = layers.rms_norm(x, lp["norm_x"], cfg.norm_eps)
        h = attn.attention(lp["cross_attn"], cfg, h, kv_x=enc_out, rope=False)
        x = x + h
        h = layers.rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = shard_act(x + layers.mlp(lp["mlp"], h), ("batch", "seq", None))
        return x, None

    body = jax.checkpoint(layer) if cfg.remat else layer
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return layers.rms_norm(x, params["final_norm"], cfg.norm_eps)


def encdec_loss(params, cfg, frames, tokens, labels) -> Array:
    """Teacher-forced seq2seq CE (chunked over the decoder sequence)."""
    from repro.models.lm import LOSS_CHUNK

    enc_out = encode(params, cfg, frames)
    hidden = _dec_trunk(params, cfg, tokens, enc_out)
    B, S, D = hidden.shape
    table = params["embed"]

    pad = (-S) % LOSS_CHUNK
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
    nc = (S + pad) // LOSS_CHUNK
    hc = hidden.reshape(B, nc, LOSS_CHUNK, D).swapaxes(0, 1)
    lc = labels.reshape(B, nc, LOSS_CHUNK).swapaxes(0, 1)

    def chunk_loss(carry, inp):
        h, l = inp
        logits = layers.unembed(h, table)
        mask = l >= 0
        lsafe = jnp.where(mask, l, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lsafe[..., None], axis=-1)[..., 0]
        tot, cnt = carry
        return (tot + jnp.sum(jnp.where(mask, logz - gold, 0.0)),
                cnt + jnp.sum(mask)), None

    body = jax.checkpoint(chunk_loss) if cfg.remat else chunk_loss
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (hc, lc)
    )
    return tot / jnp.maximum(cnt, 1)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_encdec_caches(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = cfg.compute_dtype
    L = cfg.n_layers
    kv = (L, batch, cfg.n_kv_heads, max_len, cfg.d_head)
    cross = (L, batch, cfg.n_kv_heads, cfg.encoder.n_ctx, cfg.d_head)
    return {
        "k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt),
        "ck": jnp.zeros(cross, dt), "cv": jnp.zeros(cross, dt),
    }


def encdec_prefill(params, cfg, frames, tokens, max_len):
    """Encode audio, prefill the decoder prompt, build all caches."""
    dt = cfg.compute_dtype
    enc_out = encode(params, cfg, frames)
    B, S = tokens.shape
    x = layers.embed(params["embed"], tokens, dt)
    x = x + params["dec_pos"].astype(dt)[jnp.arange(S)][None]

    def layer(x, lp):
        h = layers.rms_norm(x, lp["norm1"], cfg.norm_eps)
        h, (kT, vT) = attn.attention_prefill(lp["self_attn"], cfg, h, None)
        # attention_prefill applies rope; whisper wants none -> use plain path
        x = x + h
        h = layers.rms_norm(x, lp["norm_x"], cfg.norm_eps)
        # cross K/V computed once here
        q, ck, cv = attn._project_qkv(lp["cross_attn"], cfg, h, enc_out)
        ckT, cvT = jnp.swapaxes(ck, 1, 2), jnp.swapaxes(cv, 1, 2)
        o = attn._sdpa(
            jnp.swapaxes(q, 1, 2), ckT, cvT, causal=False, window=None,
            softcap=0.0, scale=cfg.d_head ** -0.5, impl=cfg.attn_impl,
        )
        o = jnp.swapaxes(o, 1, 2).reshape(B, S, cfg.n_heads * cfg.d_head)
        x = x + o @ lp["cross_attn"]["wo"].astype(dt)
        h = layers.rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + layers.mlp(lp["mlp"], h)
        pad = max_len - S
        cache = {
            "k": jnp.pad(kT, ((0, 0), (0, 0), (0, pad), (0, 0))),
            "v": jnp.pad(vT, ((0, 0), (0, 0), (0, pad), (0, 0))),
            "ck": ckT, "cv": cvT,
        }
        return x, cache

    body = jax.checkpoint(layer) if cfg.remat else layer
    x, caches = jax.lax.scan(body, x, params["dec_layers"])
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(x[:, -1], params["embed"])
    return logits, caches


def encdec_decode_step(params, cfg, caches, token, pos):
    """One decoder token. caches from init_encdec_caches/encdec_prefill."""
    dt = cfg.compute_dtype
    B = token.shape[0]
    x = layers.embed(params["embed"], token, dt)
    x = x + params["dec_pos"].astype(dt)[pos][:, None]

    def layer(x, inp):
        lp, cache = inp
        h = layers.rms_norm(x, lp["norm1"], cfg.norm_eps)
        h, (kc, vc) = attn.attention_decode(
            lp["self_attn"], cfg, h, cache["k"], cache["v"], pos
        )
        x = x + h
        h = layers.rms_norm(x, lp["norm_x"], cfg.norm_eps)
        q, _, _ = attn._project_qkv(lp["cross_attn"], cfg, h, h)  # q only
        o = attn._sdpa(
            jnp.swapaxes(q, 1, 2), cache["ck"], cache["cv"],
            causal=False, window=None, softcap=0.0,
            scale=cfg.d_head ** -0.5, impl=cfg.attn_impl,
        )
        o = jnp.swapaxes(o, 1, 2).reshape(B, 1, cfg.n_heads * cfg.d_head)
        x = x + o @ lp["cross_attn"]["wo"].astype(dt)
        h = layers.rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + layers.mlp(lp["mlp"], h)
        return x, {"k": kc, "v": vc, "ck": cache["ck"], "cv": cache["cv"]}

    x, new_caches = jax.lax.scan(layer, x, (params["dec_layers"], caches))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(x[:, 0], params["embed"])
    return logits, new_caches
