"""Decoder-only LM covering dense / MoE / SSM / hybrid / VLM families.

Layer stack = ``n_periods`` repetitions of a ``period``-long heterogeneous
pattern (config.py).  The stack is a ``lax.scan`` over stacked period
parameters — HLO size stays O(period), which keeps the 512-device dry-run
compile tractable for 94-layer models — with optional ``jax.checkpoint``
(remat) around each period for training memory.

Cross-entropy is computed in sequence chunks (scan) so the [B, S, V] logits
tensor is never materialized (V up to 256k in the assigned pool).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import Array

from repro.dist.act_sharding import shard_act
from repro.models import attention as attn
from repro.models import layers, moe, ssm
from repro.models.config import ModelConfig

AUX_LOSS_WEIGHT = 0.01
LOSS_CHUNK = 512


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_period(key, cfg: ModelConfig) -> dict:
    p = {}
    keys = jax.random.split(key, cfg.period)
    for i in range(cfg.period):
        k_mix, k_mlp = jax.random.split(keys[i])
        sub: dict = {"norm1": layers.init_rms_norm(cfg.d_model)}
        if cfg.mixer_kind(i) == "attn":
            sub["mixer"] = attn.init_attention(k_mix, cfg)
        else:
            sub["mixer"] = ssm.init_ssm(k_mix, cfg)
        mk = cfg.mlp_kind(i)
        if mk != "none":
            sub["norm2"] = layers.init_rms_norm(cfg.d_model)
            sub["mlp"] = (moe.init_moe(k_mlp, cfg) if mk == "moe"
                          else layers.init_mlp(k_mlp, cfg.d_model, cfg.d_ff))
        p[f"sub{i}"] = sub
    return p


def init_lm(key, cfg: ModelConfig) -> dict:
    k_embed, k_head, k_layers = jax.random.split(key, 3)
    params = {
        "embed": layers.init_embed(k_embed, cfg.vocab, cfg.d_model),
        "final_norm": layers.init_rms_norm(cfg.d_model),
        "periods": jax.vmap(lambda k: _init_period(k, cfg))(
            jax.random.split(k_layers, cfg.n_periods)
        ),
    }
    if not cfg.tie_embeddings:
        params["head"] = layers.trunc_normal(k_head, (cfg.d_model, cfg.vocab))
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill trunk)
# ---------------------------------------------------------------------------

def _apply_period(cfg: ModelConfig, pp: dict, x: Array, positions) -> tuple[Array, Array]:
    from jax.ad_checkpoint import checkpoint_name

    aux = jnp.zeros((), jnp.float32)
    x = shard_act(x, ("batch", "seq", None))
    for i in range(cfg.period):
        sub = pp[f"sub{i}"]
        h = layers.rms_norm(x, sub["norm1"], cfg.norm_eps)
        if cfg.mixer_kind(i) == "attn":
            h = attn.attention(
                sub["mixer"], cfg, h, positions,
                causal=True, window=cfg.layer_window(i),
            )
        else:
            h = ssm.ssm_apply(sub["mixer"], cfg, h, impl=cfg.attn_impl)
        h = checkpoint_name(h, "remat_ckpt")   # skip mixer in bwd replay
        x = x + h
        mk = cfg.mlp_kind(i)
        if mk != "none":
            h = layers.rms_norm(x, sub["norm2"], cfg.norm_eps)
            if mk == "moe":
                h, a = moe.moe_apply(sub["mlp"], cfg, h)
                aux = aux + a
            else:
                h = checkpoint_name(layers.mlp(sub["mlp"], h), "remat_ckpt")
            x = x + h
        x = shard_act(x, ("batch", "seq", None))
    return x, aux


def _embed_inputs(params, cfg: ModelConfig, tokens, frontend_embeds):
    x = layers.embed(params["embed"], tokens, cfg.compute_dtype)
    if cfg.family in ("vlm",) or cfg.n_frontend_tokens:
        assert frontend_embeds is not None, f"{cfg.name} needs frontend embeds"
        x = jnp.concatenate([frontend_embeds.astype(cfg.compute_dtype), x], axis=1)
    return x


def forward_hidden(
    params: dict,
    cfg: ModelConfig,
    tokens: Array,                  # [B, S_tok]
    positions: Array | None = None, # [B, S] or [3, B, S]
    frontend_embeds: Array | None = None,
) -> tuple[Array, Array]:
    """Returns (final hidden [B, S, D], aux loss)."""
    x = shard_act(
        _embed_inputs(params, cfg, tokens, frontend_embeds),
        ("batch", "seq", None),
    )

    body = functools.partial(_apply_period, cfg)
    if cfg.remat:
        if cfg.remat_policy == "save_named":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.save_only_these_names(
                    "remat_ckpt"),
            )
        else:
            body = jax.checkpoint(body)

    if cfg.scan_layers:
        def scan_fn(carry, pp):
            y, aux = body(pp, carry, positions)
            return y, aux

        x, auxes = jax.lax.scan(scan_fn, x, params["periods"])
        aux = jnp.sum(auxes)
    else:
        aux = jnp.zeros((), jnp.float32)
        for n in range(cfg.n_periods):
            pp = jax.tree.map(lambda a, n=n: a[n], params["periods"])
            x, a = body(pp, x, positions)
            aux = aux + a
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def _unembed_table(params, cfg):
    return params["embed"] if cfg.tie_embeddings else params["head"]


def lm_loss(
    params: dict,
    cfg: ModelConfig,
    tokens: Array,
    labels: Array,                  # [B, S_total] (-100 = masked)
    positions: Array | None = None,
    frontend_embeds: Array | None = None,
) -> Array:
    """Mean next-token cross-entropy, computed in sequence chunks."""
    hidden, aux = forward_hidden(params, cfg, tokens, positions, frontend_embeds)
    B, S, D = hidden.shape
    table = _unembed_table(params, cfg)

    pad = (-S) % LOSS_CHUNK
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
    nc = (S + pad) // LOSS_CHUNK
    hc = hidden.reshape(B, nc, LOSS_CHUNK, D).swapaxes(0, 1)
    lc = labels.reshape(B, nc, LOSS_CHUNK).swapaxes(0, 1)

    def chunk_loss(carry, inp):
        h, l = inp
        h = shard_act(h, ("batch", None, None))
        logits = shard_act(
            layers.unembed(h, table, cfg.final_softcap),         # f32 [B,C,V]
            ("batch", None, "model"),
        )
        mask = l >= 0
        lsafe = jnp.where(mask, l, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lsafe[..., None], axis=-1)[..., 0]
        nll = jnp.where(mask, logz - gold, 0.0)
        tot, cnt = carry
        return (tot + jnp.sum(nll), cnt + jnp.sum(mask)), None

    body = jax.checkpoint(chunk_loss) if cfg.remat else chunk_loss
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (hc, lc)
    )
    return tot / jnp.maximum(cnt, 1) + AUX_LOSS_WEIGHT * aux


def lm_logits(params, cfg, tokens, positions=None, frontend_embeds=None):
    """Full logits (small models / examples only)."""
    hidden, _ = forward_hidden(params, cfg, tokens, positions, frontend_embeds)
    return layers.unembed(hidden, _unembed_table(params, cfg), cfg.final_softcap)


# ---------------------------------------------------------------------------
# serving: prefill + decode with per-period caches
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Stacked per-period cache pytree (attn KV + ssm conv/state slots)."""
    dt = cfg.compute_dtype
    caches: dict = {}
    for i in range(cfg.period):
        if cfg.mixer_kind(i) == "attn":
            shape = (cfg.n_periods, batch, cfg.n_kv_heads, max_len, cfg.d_head)
            caches[f"sub{i}"] = {
                "k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)
            }
        else:
            s = cfg.ssm
            di = s.d_inner(cfg.d_model)
            H = s.n_ssm_heads(cfg.d_model)
            conv_dim = di + 2 * s.n_groups * s.d_state
            caches[f"sub{i}"] = {
                "conv": jnp.zeros(
                    (cfg.n_periods, batch, s.conv_width - 1, conv_dim), dt
                ),
                "state": jnp.zeros(
                    (cfg.n_periods, batch, H, s.head_dim, s.d_state), jnp.float32
                ),
            }
    return caches


def decode_step(
    params: dict,
    cfg: ModelConfig,
    caches: dict,
    token: Array,     # [B, 1] int32
    pos: Array,       # [B] int32 current position
) -> tuple[Array, dict]:
    """One decode step: logits [B, V] + updated caches."""
    x = layers.embed(params["embed"], token, cfg.compute_dtype)  # [B,1,D]

    def period_step(x, inp):
        pp, cache_p = inp
        new_cache = {}
        for i in range(cfg.period):
            sub = pp[f"sub{i}"]
            h = layers.rms_norm(x, sub["norm1"], cfg.norm_eps)
            if cfg.mixer_kind(i) == "attn":
                h, (kc, vc) = attn.attention_decode(
                    sub["mixer"], cfg, h,
                    cache_p[f"sub{i}"]["k"], cache_p[f"sub{i}"]["v"], pos,
                    window=cfg.layer_window(i),
                )
                new_cache[f"sub{i}"] = {"k": kc, "v": vc}
            else:
                h, conv_s, ssm_s = ssm.ssm_decode(
                    sub["mixer"], cfg, h,
                    cache_p[f"sub{i}"]["conv"], cache_p[f"sub{i}"]["state"],
                )
                new_cache[f"sub{i}"] = {"conv": conv_s, "state": ssm_s}
            x = x + h
            mk = cfg.mlp_kind(i)
            if mk != "none":
                h = layers.rms_norm(x, sub["norm2"], cfg.norm_eps)
                if mk == "moe":
                    h, _ = moe.moe_apply(sub["mlp"], cfg, h)
                else:
                    h = layers.mlp(sub["mlp"], h)
                x = x + h
        return x, new_cache

    x, new_caches = jax.lax.scan(period_step, x, (params["periods"], caches))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(x[:, 0], _unembed_table(params, cfg), cfg.final_softcap)
    return logits, new_caches


def prefill(
    params: dict,
    cfg: ModelConfig,
    tokens: Array,                  # [B, S]
    max_len: int,
    positions: Array | None = None,
    frontend_embeds: Array | None = None,
) -> tuple[Array, dict]:
    """Process a prompt, producing last-position logits + filled caches."""
    x = _embed_inputs(params, cfg, tokens, frontend_embeds)
    B, S, D = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def period_fn(x, pp):
        cache_out = {}
        for i in range(cfg.period):
            sub = pp[f"sub{i}"]
            h = layers.rms_norm(x, sub["norm1"], cfg.norm_eps)
            if cfg.mixer_kind(i) == "attn":
                h, (kT, vT) = attn.attention_prefill(
                    sub["mixer"], cfg, h, positions, window=cfg.layer_window(i)
                )
                pad = max_len - S
                cache_out[f"sub{i}"] = {
                    "k": jnp.pad(kT, ((0, 0), (0, 0), (0, pad), (0, 0))),
                    "v": jnp.pad(vT, ((0, 0), (0, 0), (0, pad), (0, 0))),
                }
            else:
                h, conv_s, ssm_s = ssm.ssm_prefill(sub["mixer"], cfg, h)
                cache_out[f"sub{i}"] = {"conv": conv_s, "state": ssm_s}
            x = x + h
            mk = cfg.mlp_kind(i)
            if mk != "none":
                h = layers.rms_norm(x, sub["norm2"], cfg.norm_eps)
                if mk == "moe":
                    h, _ = moe.moe_apply(sub["mlp"], cfg, h)
                else:
                    h = layers.mlp(sub["mlp"], h)
                x = x + h
        return x, cache_out

    body = jax.checkpoint(period_fn) if cfg.remat else period_fn
    x, caches = jax.lax.scan(body, x, params["periods"])
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(
        x[:, -1], _unembed_table(params, cfg), cfg.final_softcap
    )
    return logits, caches
