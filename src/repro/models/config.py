"""Model configuration schema covering the whole assigned architecture pool.

One ``ModelConfig`` describes any of: dense GQA transformers (phi3, qwen3,
gemma2, internlm2, qwen2-vl), MoE transformers (qwen3-moe, granite-moe),
pure SSM (mamba2), hybrid SSM+attention+MoE (jamba), and encoder-decoder
(whisper).  Heterogeneous layer patterns (jamba's 1-attention-per-8, gemma2's
local/global alternation, jamba's MoE-every-other) are expressed as a
repeating *period*: the layer stack is ``n_layers / period`` repetitions of a
``period``-long pattern, which is what the scan-over-layers compiler path
iterates (one period = one scan step, keeping HLO size O(period) instead of
O(n_layers)).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                 # per-expert hidden width
    every: int = 1            # MoE replaces dense MLP on layers p % every == every-1
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128        # N
    head_dim: int = 64        # P
    n_groups: int = 1         # G (B/C projections shared per group)
    conv_width: int = 4
    expand: int = 2           # d_inner = expand * d_model
    chunk: int = 128          # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    n_layers: int
    n_ctx: int = 1500         # whisper: 30 s of audio -> 1500 frames


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                 # dense-MLP hidden width (MoE archs: unused or
    vocab: int                # the dense layers of a hybrid)
    d_head: int | None = None # default d_model // n_heads
    # --- attention variants ---
    rope_theta: float = 10_000.0
    qk_norm: bool = False                 # qwen3
    attn_softcap: float = 0.0             # gemma2 attention-logit softcap
    final_softcap: float = 0.0            # gemma2 final-logit softcap
    sliding_window: int | None = None     # window for "local" layers
    global_every: int = 0                 # 0: all layers global; k: layer
                                          # p%k==k-1 global, others local
    mrope_sections: tuple[int, ...] | None = None  # qwen2-vl M-RoPE (t,h,w)
    attn_every: int = 1                   # 1: attention every layer;
                                          # k: only p%k==k-1 (jamba); 0: none
    # --- substructures ---
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    encoder: EncoderConfig | None = None
    frontend: Literal[None, "audio", "vision"] = None
    n_frontend_tokens: int = 0            # stub embeddings prepended (vlm)
    pos_embed: Literal["rope", "learned"] = "rope"  # whisper: learned absolute
    max_position: int = 0                 # learned-pos table size (0 = unused)
    # --- numerics / compile strategy ---
    tie_embeddings: bool = False
    dtype: str = "float32"                # activation/weight compute dtype
    attn_impl: Literal["xla", "pallas"] = "xla"
    remat: bool = True                    # checkpoint each scan period
    remat_policy: str = "none"            # "none" | "save_named": keep values
                                          # tagged remat_ckpt (e.g. the MoE
                                          # combine) out of the bwd replay
    scan_layers: bool = True
    norm_eps: float = 1e-6

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.d_head is None:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.n_layers % self.period != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"period={self.period}"
            )

    @property
    def period(self) -> int:
        p = 1
        for k in (self.attn_every, self.global_every,
                  self.moe.every if self.moe else 1):
            p = math.lcm(p, max(k, 1))
        return p

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    def mixer_kind(self, p: int) -> str:
        """'attn' | 'ssm' for pattern position p (within a period)."""
        if self.attn_every == 0:
            return "ssm"
        if self.ssm is not None and self.attn_every > 1:
            return "attn" if p % self.attn_every == self.attn_every - 1 else "ssm"
        return "attn"

    def mlp_kind(self, p: int) -> str:
        """'moe' | 'dense' | 'none' for pattern position p."""
        if self.ssm is not None and self.moe is None and self.attn_every == 0:
            return "none"                 # pure mamba2: the block IS the mixer
        if self.moe and p % self.moe.every == self.moe.every - 1:
            return "moe"
        return "dense"

    def layer_window(self, p: int) -> int | None:
        """Sliding window for pattern position p (None = global)."""
        if self.global_every == 0:
            return self.sliding_window
        is_global = p % self.global_every == self.global_every - 1
        return None if is_global else self.sliding_window

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing -> long_500k applies."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are (or contain) decoders

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for 6ND roofline."""
        D, V = self.d_model, self.vocab
        kv_dim = self.n_kv_heads * self.d_head
        q_dim = self.n_heads * self.d_head
        per_period = 0
        for p in range(self.period):
            if self.mixer_kind(p) == "attn":
                per_period += D * (q_dim + 2 * kv_dim) + q_dim * D
            else:
                s = self.ssm
                di = s.d_inner(D)
                H = s.n_ssm_heads(D)
                bc = 2 * s.n_groups * s.d_state
                per_period += D * (2 * di + bc + H) + di * s.conv_width + di * D
            mk = self.mlp_kind(p)
            if mk == "dense":
                per_period += 3 * D * self.d_ff
            elif mk == "moe":
                per_period += self.moe.n_experts * 3 * D * self.moe.d_ff
                per_period += D * self.moe.n_experts  # router
            per_period += 2 * D  # two RMSNorm scales
        total = per_period * self.n_periods + D  # + final norm
        total += V * D + (0 if self.tie_embeddings else V * D)
        if self.encoder:
            # self-attn (no cross kv cost here: decoder owns cross-attn q/o,
            # encoder supplies k/v) + MLP + norms, per encoder layer
            enc = (D * (q_dim + 2 * kv_dim) + q_dim * D
                   + 3 * D * self.d_ff + 4 * D) * self.encoder.n_layers
            # decoder cross-attention adds q/k/v/o per decoder layer
            enc += (D * (q_dim + 2 * kv_dim) + q_dim * D + D) * self.n_layers
            total += enc
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        moe_layers = self.n_layers // self.moe.every
        expert_p = 3 * self.d_model * self.moe.d_ff
        inactive = moe_layers * (self.moe.n_experts - self.moe.top_k) * expert_p
        return int(full - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(applies?, reason-if-not) — the DESIGN.md §Arch-applicability rules."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full quadratic attention: 500k decode needs sub-quadratic mixing"
    return True, ""
