"""Mixture-of-Experts with top-k routing, capacity bound, and explicit EP.

Two execution paths with identical semantics (tests assert equivalence):

* **local** (no mesh context): sort-based dispatch on one device — the
  reference implementation and the CPU smoke/test path.

* **shard_map EP** (active ``activation_shardings`` context): the TPU-native
  layout.  Tokens are sharded over the batch axes and *replicated over
  "model"*; experts are sharded E-over-"model" (EP) and F-over-"data", so
  expert weights are 256-way sharded at rest.  Two interchangeable
  communication schedules, chosen statically by payload volume:

  - **token-gather** (prefill/decode: few tokens): all-gather (data) the
    [E_loc, C_d, D] dispatch buffers, run the FFN with F-sharded weights,
    psum_scatter (data) the partial outputs back to their owning shard.
  - **weight-gather** (training: many tokens): all-gather (data) the
    E_loc expert weights instead (ZeRO-3-style transient gather), keep every
    token local — zero dispatch communication. Measured on
    qwen3-moe x train_4k this is ~5x less traffic (302 MB vs 2.7 GB per
    layer-device); see EXPERIMENTS.md §Perf.

  Both end with a psum over "model" (the expert columns). GSPMD cannot infer
  either schedule from a global scatter/gather formulation (measured: it
  replicates the dispatch and emits 26 TB of all-reduce per step) — this is
  the framework's hardware-adaptation of expert parallelism (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array
from jax.sharding import PartitionSpec as P

from repro.dist import act_sharding
from repro.dist.compat import shard_map
from repro.models import layers


def init_moe(key, cfg) -> dict:
    m = cfg.moe
    D = cfg.d_model
    kr, k1, k2, k3 = jax.random.split(key, 4)
    E, F = m.n_experts, m.d_ff
    return {
        "router": layers.trunc_normal(kr, (D, E)),
        "w_gate": layers.trunc_normal(k1, (E, D, F)),
        "w_up": layers.trunc_normal(k2, (E, D, F)),
        "w_down": layers.trunc_normal(k3, (E, F, D)),
    }


def _capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    cap = int(n_tokens * top_k / n_experts * factor) + 1
    return max(8, ((cap + 7) // 8) * 8)  # pad to sublane multiple


def _route(xt: Array, router: Array, E: int, K: int):
    """Shared router math: (gates [T,K], experts [T,K], me [E], ce [E]).

    aux = E * sum(me * ce) — callers combine AFTER averaging me/ce over all
    token shards (mean-of-products != product-of-means)."""
    logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=1),
        axis=0,
    )
    return gate_vals, expert_ids, me, ce


def _dispatch_slots(expert_ids_flat: Array, n_segments: int, cap: int):
    """FCFS slot assignment within each expert (stable sort + prefix count)."""
    order = jnp.argsort(expert_ids_flat, stable=True)
    e_sorted = expert_ids_flat[order]
    ones = jnp.ones_like(e_sorted, jnp.int32)
    start = jnp.zeros((n_segments + 2,), jnp.int32).at[
        jnp.clip(e_sorted, 0, n_segments) + 1
    ].add(ones)
    offsets = jnp.cumsum(start)[:-1]
    slot = jnp.arange(e_sorted.shape[0]) - offsets[jnp.clip(e_sorted, 0, n_segments)]
    keep = (slot < cap) & (e_sorted < n_segments)
    return order, e_sorted, slot, keep


def _expert_ffn(params, xe: Array, dt) -> Array:
    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(dt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                      params["w_down"].astype(dt))


def _moe_local(params: dict, cfg, x: Array) -> tuple[Array, Array]:
    """Single-device reference path."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    xt = x.reshape(T, D)
    dt = x.dtype

    gate_vals, expert_ids, me, ce = _route(xt, params["router"], E, K)
    aux = E * jnp.sum(me * ce)
    cap = _capacity(T, E, K, m.capacity_factor)
    flat_e = expert_ids.reshape(T * K)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = gate_vals.reshape(T * K)
    order, e_sorted, slot, keep = _dispatch_slots(flat_e, E, cap)
    t_sorted = flat_t[order]
    g_sorted = flat_g[order]
    slot_c = jnp.where(keep, slot, 0)
    e_safe = jnp.clip(e_sorted, 0, E - 1)

    xe = jnp.zeros((E, cap, D), dt).at[e_safe, slot_c].add(
        jnp.where(keep[:, None], xt[t_sorted], 0).astype(dt)
    )
    ye = _expert_ffn(params, xe, dt)
    contrib = ye[e_safe, slot_c] * (g_sorted * keep)[:, None].astype(dt)
    out = jnp.zeros((T, D), dt).at[t_sorted].add(contrib)
    return out.reshape(B, S, D), aux


def _moe_shard_map(params: dict, cfg, x: Array, state) -> tuple[Array, Array]:
    """Explicit EP schedule under shard_map (see module docstring)."""
    mesh, rules, seq_par = state
    if rules.tp is None:                     # fsdp strategy: no EP columns
        return _moe_local(params, cfg, x)
    m = cfg.moe
    tp = rules.tp
    batch_axes = rules.batch                       # ("data",) or ("pod","data")
    ntp = mesh.shape[tp]
    ndp = 1
    for a in batch_axes:
        ndp *= mesh.shape[a]
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k
    if E % ntp != 0 or B % ndp != 0:
        return _moe_local(params, cfg, x)          # fallback: let GSPMD cope
    E_loc = E // ntp
    T_loc = (B // ndp) * S
    C_d = _capacity(T_loc, E, K, m.capacity_factor)
    dt = x.dtype
    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    # under sequence parallelism the residual stream is S-sharded over tp:
    # emit the combine as a reduce-scatter straight into that layout instead
    # of a psum followed by a re-shard (halves the combine traffic)
    sp_out = bool(seq_par) and S % ntp == 0

    def local_fn(x_loc, router, wg, wu, wd):
        B_loc = x_loc.shape[0]
        xt = x_loc.reshape(B_loc * S, D)
        gate_vals, expert_ids, me, ce = _route(xt, router, E, K)
        me = jax.lax.pmean(me, batch_axes)
        ce = jax.lax.pmean(ce, batch_axes)
        aux = E * jnp.sum(me * ce)

        mcol = jax.lax.axis_index(tp)
        flat_e = expert_ids.reshape(-1) - mcol * E_loc      # local expert id
        flat_e = jnp.where((flat_e >= 0) & (flat_e < E_loc), flat_e, E_loc)
        flat_t = jnp.repeat(jnp.arange(T_loc), K)
        flat_g = gate_vals.reshape(-1)
        order, e_sorted, slot, keep = _dispatch_slots(flat_e, E_loc, C_d)
        t_sorted = flat_t[order]
        g_sorted = flat_g[order]
        slot_c = jnp.where(keep, slot, 0)
        e_safe = jnp.clip(e_sorted, 0, E_loc - 1)

        # dispatch buffer for MY experts from MY tokens (no comm: tokens are
        # replicated over the model axis)
        xe = jnp.zeros((E_loc, C_d, D), dt).at[e_safe, slot_c].add(
            jnp.where(keep[:, None], xt[t_sorted], 0).astype(dt)
        )

        # choose the cheaper collective payload (see module docstring)
        token_bytes = E_loc * C_d * ndp * D
        weight_bytes = 3 * E_loc * D * (m.d_ff // ndp) * ndp
        if weight_bytes < token_bytes:
            # weight-gather schedule: tokens stay local
            wg_f = jax.lax.all_gather(wg, batch_axes, axis=2, tiled=True)
            wu_f = jax.lax.all_gather(wu, batch_axes, axis=2, tiled=True)
            wd_f = jax.lax.all_gather(wd, batch_axes, axis=1, tiled=True)
            g = jnp.einsum("ecd,edf->ecf", xe, wg_f.astype(dt))
            u = jnp.einsum("ecd,edf->ecf", xe, wu_f.astype(dt))
            ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                            wd_f.astype(dt)).astype(dt)     # [E_loc, C_d, D]
        else:
            # token-gather schedule: weights stay local (F-sharded)
            xe_all = jax.lax.all_gather(xe, batch_axes, axis=1, tiled=True)
            g = jnp.einsum("ecd,edf->ecf", xe_all, wg.astype(dt))
            u = jnp.einsum("ecd,edf->ecf", xe_all, wu.astype(dt))
            y_part = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                                wd.astype(dt)).astype(dt)   # bf16 RS
            # reduce the F-contraction AND scatter token slots back to their
            # owning data shard in one collective
            ye = jax.lax.psum_scatter(
                y_part, batch_axes, scatter_dimension=1, tiled=True
            )                                               # [E_loc, C_d, D]

        contrib = ye[e_safe, slot_c] * (g_sorted * keep)[:, None].astype(dt)
        out = jnp.zeros((T_loc, D), dt).at[t_sorted].add(contrib)
        out = out.reshape(B_loc, S, D)
        if sp_out:
            out = jax.lax.psum_scatter(                     # sum expert columns
                out, tp, scatter_dimension=1, tiled=True    # -> [B, S/ntp, D]
            )
        else:
            out = jax.lax.psum(out, tp)                     # sum expert columns
        from jax.ad_checkpoint import checkpoint_name
        out = checkpoint_name(out, "remat_ckpt")
        return out, aux

    out, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None),
            P(None, None),
            P(tp, None, "data"),
            P(tp, None, "data"),
            P(tp, "data", None),
        ),
        out_specs=(P(bspec, tp if sp_out else None, None), P()),
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    return out, aux


def moe_apply(params: dict, cfg, x: Array) -> tuple[Array, Array]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar)."""
    state = act_sharding.current_state()
    if state is not None:
        return _moe_shard_map(params, cfg, x, state)
    return _moe_local(params, cfg, x)
