"""Unified model API: build any assigned architecture from its ModelConfig.

``Model`` wraps init / train-loss / prefill / decode behind one interface and
produces ``input_specs`` — ShapeDtypeStruct stand-ins for every entry point x
assigned shape cell — which is what the multi-pod dry-run lowers against
(no allocation ever happens for the full configs).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import encdec, lm
from repro.models.config import ModelConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ----------------------------------------------------------------- init
    def init(self, key) -> dict:
        if self.cfg.family == "encdec":
            return encdec.init_encdec(key, self.cfg)
        return lm.init_lm(key, self.cfg)

    # ----------------------------------------------------------------- train
    def loss(self, params, batch: dict[str, Any]):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.encdec_loss(
                params, cfg, batch["frames"], batch["tokens"], batch["labels"]
            )
        return lm.lm_loss(
            params, cfg, batch["tokens"], batch["labels"],
            positions=batch.get("positions"),
            frontend_embeds=batch.get("frontend_embeds"),
        )

    # ----------------------------------------------------------------- serve
    def prefill(self, params, batch: dict[str, Any], max_len: int):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.encdec_prefill(
                params, cfg, batch["frames"], batch["tokens"], max_len
            )
        return lm.prefill(
            params, cfg, batch["tokens"], max_len,
            positions=batch.get("positions"),
            frontend_embeds=batch.get("frontend_embeds"),
        )

    def decode_step(self, params, caches, token, pos):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.encdec_decode_step(params, cfg, caches, token, pos)
        return lm.decode_step(params, cfg, caches, token, pos)

    def init_caches(self, batch: int, max_len: int):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.init_encdec_caches(cfg, batch, max_len)
        return lm.init_caches(cfg, batch, max_len)

    # ------------------------------------------------------------ dry-run IO
    def param_specs(self, key=None) -> Any:
        """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
        k = jax.random.PRNGKey(0)
        return jax.eval_shape(self.init, k)

    def input_specs(self, shape: ShapeSpec) -> dict[str, Any]:
        """ShapeDtypeStruct stand-ins for one assigned (shape) cell.

        train   -> kwargs for ``loss``;
        prefill -> kwargs for ``prefill``;
        decode  -> kwargs for ``decode_step`` (incl. cache specs).
        """
        cfg = self.cfg
        i32 = jnp.int32
        B, S = shape.global_batch, shape.seq_len
        sds = jax.ShapeDtypeStruct

        if shape.kind in ("train", "prefill"):
            batch: dict[str, Any] = {}
            s_tok = S
            if cfg.family == "encdec":
                batch["frames"] = sds(
                    (B, cfg.encoder.n_ctx, cfg.d_model), cfg.compute_dtype
                )
            if cfg.family == "vlm" and cfg.n_frontend_tokens:
                s_tok = S - cfg.n_frontend_tokens
                batch["frontend_embeds"] = sds(
                    (B, cfg.n_frontend_tokens, cfg.d_model), cfg.compute_dtype
                )
                batch["positions"] = sds((3, B, S), i32)
            batch["tokens"] = sds((B, s_tok), i32)
            if shape.kind == "train":
                batch["labels"] = sds((B, S), i32)
            return {"batch": batch}

        # decode: one new token against a max_len context
        specs = {
            "caches": jax.eval_shape(
                lambda: self.init_caches(B, S)
            ),
            "token": sds((B, 1), i32),
            "pos": sds((B,), i32),
        }
        return specs


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
