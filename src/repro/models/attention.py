"""GQA attention: XLA flash (online-softmax scan) and Pallas paths + KV cache.

The default ("xla") path is an online-softmax scan over KV chunks — the flash
algorithm expressed in jnp — so activation memory is O(S * chunk) on every
backend and the 32k prefill lowers without an S x S score tensor.  The
"pallas" path calls the hand-tiled TPU kernel (kernels/flash_attention.py).

Supports: GQA (no KV repetition in HBM on the XLA path either — grouped
einsum), causal + sliding window + attention-logit softcap, qk-norm,
RoPE / M-RoPE, cross-attention (whisper), and single-token decode against a
preallocated cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

from repro.dist.act_sharding import shard_act
from repro.models import layers


def init_attention(key, cfg, cross: bool = False) -> dict:
    D = cfg.d_model
    q_dim = cfg.n_heads * cfg.d_head
    kv_dim = cfg.n_kv_heads * cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.trunc_normal(ks[0], (D, q_dim)),
        "wk": layers.trunc_normal(ks[1], (D, kv_dim)),
        "wv": layers.trunc_normal(ks[2], (D, kv_dim)),
        "wo": layers.trunc_normal(ks[3], (q_dim, D)),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rms_norm(cfg.d_head)
        p["k_norm"] = layers.init_rms_norm(cfg.d_head)
    return p


def flash_xla(
    q: Array,  # [B, Hq, Sq, D]
    k: Array,  # [B, Hk, Sk, D]
    v: Array,
    *,
    causal: bool,
    window: int | None,
    softcap: float,
    scale: float,
    chunk: int = 512,
) -> Array:
    """Online-softmax scan over KV chunks (flash attention in XLA)."""
    B, Hq, Sq, D = q.shape
    Hk, Sk = k.shape[1], k.shape[2]
    g = Hq // Hk
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = (Sk + pad) // chunk
    qg = q.reshape(B, Hk, g, Sq, D).astype(jnp.float32)
    kc = k.reshape(B, Hk, nk, chunk, D).astype(jnp.float32)
    vc = v.reshape(B, Hk, nk, chunk, D).astype(jnp.float32)
    row = jnp.arange(Sq)[:, None] + (Sk - Sq)                   # [Sq,1]

    def step(carry, inp):
        acc, m, l = carry
        kj, vj, j = inp                                         # [B,Hk,chunk,D]
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kj) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        col = j * chunk + jnp.arange(chunk)[None, :]            # [1,chunk]
        valid = col < Sk
        if causal:
            valid &= col <= row
        if window is not None:
            valid &= col > row - window
        s = jnp.where(valid[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(s > -5e29, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, vj)
        return (acc, m_new, l), None

    acc0 = shard_act(jnp.zeros((B, Hk, g, Sq, D), jnp.float32),
                     ("batch", "model", None, None, None))
    m0 = shard_act(jnp.full((B, Hk, g, Sq, 1), -1e30, jnp.float32),
                   ("batch", "model", None, None, None))
    l0 = shard_act(jnp.zeros((B, Hk, g, Sq, 1), jnp.float32),
                   ("batch", "model", None, None, None))
    (acc, _, l), _ = jax.lax.scan(
        step, (acc0, m0, l0),
        (jnp.moveaxis(kc, 2, 0), jnp.moveaxis(vc, 2, 0), jnp.arange(nk)),
    )
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(B, Hq, Sq, D).astype(q.dtype)


def _sdpa(q, k, v, *, causal, window, softcap, scale, impl):
    if impl == "pallas":
        from repro.kernels import ops

        return ops.flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale
        )
    return flash_xla(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale
    )


def _project_qkv(params, cfg, x, kv_x=None):
    """Project and head-split. kv_x: cross-attention source (defaults x)."""
    dt = x.dtype
    B, S, _ = x.shape
    src = x if kv_x is None else kv_x
    Skv = src.shape[1]
    q = (x @ params["wq"].astype(dt)).reshape(B, S, cfg.n_heads, cfg.d_head)
    k = (src @ params["wk"].astype(dt)).reshape(B, Skv, cfg.n_kv_heads, cfg.d_head)
    v = (src @ params["wv"].astype(dt)).reshape(B, Skv, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = layers.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = shard_act(q, ("batch", None, "model", None))
    k = shard_act(k, ("batch", None, "model", None))
    v = shard_act(v, ("batch", None, "model", None))
    return q, k, v


def attention(
    params: dict,
    cfg,
    x: Array,                       # [B, S, D]
    positions: Array | None = None, # [B, S] (or [3, B, S] for M-RoPE)
    *,
    causal: bool = True,
    window: int | None = None,
    kv_x: Array | None = None,      # cross-attention keys/values source
    rope: bool = True,
) -> Array:
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, kv_x)
    if rope and kv_x is None and cfg.pos_embed == "rope":
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.mrope_sections is not None and positions.ndim == 3:
            q = layers.apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = layers.apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            if positions.ndim == 3:
                positions = positions[0]
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)
    out = _sdpa(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=causal and kv_x is None, window=window,
        softcap=cfg.attn_softcap, scale=cfg.d_head ** -0.5, impl=cfg.attn_impl,
    )
    out = jnp.swapaxes(out, 1, 2).reshape(B, S, cfg.n_heads * cfg.d_head)
    return out @ params["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# KV cache (decode path)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, max_len: int, n_attn_layers: int, dtype):
    shape = (n_attn_layers, batch, cfg.n_kv_heads, max_len, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_prefill(params, cfg, x, positions, *, window=None):
    """Prefill: run attention AND return this layer's (k, v) for the cache."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x)
    if cfg.pos_embed == "rope":
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.mrope_sections is not None and positions.ndim == 3:
            q = layers.apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = layers.apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            pos = positions[0] if positions.ndim == 3 else positions
            q = layers.apply_rope(q, pos, cfg.rope_theta)
            k = layers.apply_rope(k, pos, cfg.rope_theta)
    kT, vT = jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)
    out = _sdpa(
        jnp.swapaxes(q, 1, 2), kT, vT,
        causal=True, window=window,
        softcap=cfg.attn_softcap, scale=cfg.d_head ** -0.5, impl=cfg.attn_impl,
    )
    out = jnp.swapaxes(out, 1, 2).reshape(B, S, cfg.n_heads * cfg.d_head)
    return out @ params["wo"].astype(x.dtype), (kT, vT)


def attention_decode(
    params: dict,
    cfg,
    x: Array,          # [B, 1, D]
    k_cache: Array,    # [B, Hk, L, Dh]  (L = max context, zero-padded)
    v_cache: Array,
    pos: Array,        # [B] current write position
    *,
    window: int | None = None,
):
    """One-token decode: write k/v at ``pos``, attend over the valid prefix."""
    B = x.shape[0]
    q, k, v = _project_qkv(params, cfg, x)
    posb = pos[:, None]                                        # [B,1]
    if cfg.pos_embed == "rope":
        if cfg.mrope_sections is not None:
            pos3 = jnp.broadcast_to(posb[None], (3, B, 1))
            q = layers.apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
            k = layers.apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = layers.apply_rope(q, posb, cfg.rope_theta)
            k = layers.apply_rope(k, posb, cfg.rope_theta)
    kT, vT = jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)      # [B,Hk,1,Dh]

    # length-sharded cache (kv heads don't divide tp): flash-decoding path
    from repro.dist import act_sharding as _act

    state = _act.current_state()
    if state is not None and state[1].tp is not None:
        mesh, rules, _ = state
        ntp = mesh.shape[rules.tp]
        L_ = k_cache.shape[2]
        if cfg.n_kv_heads % ntp != 0 and L_ % ntp == 0:
            out, (kc, vc) = _decode_flash_lsharded(
                cfg, mesh, rules, jnp.swapaxes(q, 1, 2), kT, vT,
                k_cache, v_cache, pos, window,
            )
            return out @ params["wo"].astype(x.dtype), (kc, vc)

    # scatter the new token into the cache at pos (per-batch dynamic index)
    oh = jax.nn.one_hot(pos, k_cache.shape[2], dtype=k_cache.dtype)  # [B,L]
    k_cache = k_cache * (1 - oh[:, None, :, None]) + oh[:, None, :, None] * kT
    v_cache = v_cache * (1 - oh[:, None, :, None]) + oh[:, None, :, None] * vT

    L = k_cache.shape[2]
    qh = jnp.swapaxes(q, 1, 2)                                 # [B,Hq,1,Dh]
    Hk = cfg.n_kv_heads
    g = cfg.n_heads // Hk
    qg = qh.reshape(B, Hk, g, 1, cfg.d_head).astype(jnp.float32)
    s = shard_act(
        jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_cache.astype(jnp.float32)),
        ("batch", "model", None, None, None),
    )
    s = s * (cfg.d_head ** -0.5)
    if cfg.attn_softcap > 0.0:
        s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
    col = jnp.arange(L)[None, :]
    valid = col <= posb                                        # [B,L]
    if window is not None:
        valid &= col > posb - window
    s = jnp.where(valid[:, None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v_cache.astype(jnp.float32))
    out = out.reshape(B, Hk * g, 1, cfg.d_head).astype(x.dtype)
    out = jnp.swapaxes(out, 1, 2).reshape(B, 1, cfg.n_heads * cfg.d_head)
    return out @ params["wo"].astype(x.dtype), (k_cache, v_cache)


# ---------------------------------------------------------------------------
# flash-decoding over a length-sharded KV cache (beyond-paper optimization)
# ---------------------------------------------------------------------------

def _decode_flash_lsharded(cfg, mesh, rules, q, kT, vT, k_cache, v_cache,
                           pos, window):
    """Decode attention with the cache sharded on its LENGTH axis.

    GSPMD's default plan all-gathers the whole KV cache every token (~GB/s
    per step, measured); instead each model-column computes an
    *unnormalized* partial softmax over its own length shard and the shards
    are merged with a log-sum-exp combine over gathered per-shard stats —
    bytes moved per layer drop from O(Hk x L x Dh) to O(Hq x Dh x ntp).

    q: [B, Hq, 1, Dh]; kT/vT: [B, Hk, 1, Dh]; caches [B, Hk, L, Dh].
    Returns (out [B, 1, Hq*Dh] replicated over tp, new caches).
    """
    from jax.sharding import PartitionSpec as P

    from repro.dist.compat import shard_map

    tp = rules.tp
    B = q.shape[0]
    Hk, L = k_cache.shape[1], k_cache.shape[2]
    g = cfg.n_heads // Hk
    scale = cfg.d_head ** -0.5
    softcap = cfg.attn_softcap

    # batch axes that divide B (long_500k: B=1 -> replicated)
    baxes = []
    prod = 1
    for a in rules.batch:
        if a in mesh.axis_names and B % (prod * mesh.shape[a]) == 0:
            baxes.append(a)
            prod *= mesh.shape[a]
    bspec = tuple(baxes) if len(baxes) > 1 else (baxes[0] if baxes else None)

    def local(q, kT, vT, kc, vc, pos):
        b_loc = q.shape[0]                                      # B / |batch axes|
        l_loc = kc.shape[2]
        col0 = jax.lax.axis_index(tp) * l_loc
        idx = pos - col0                                        # [B_loc]
        mine = (idx >= 0) & (idx < l_loc)
        oh = jnp.where(
            mine[:, None],
            jax.nn.one_hot(jnp.clip(idx, 0, l_loc - 1), l_loc,
                           dtype=kc.dtype),
            0,
        )                                                       # [B, l_loc]
        kc = kc * (1 - oh[:, None, :, None]) + oh[:, None, :, None] * kT
        vc = vc * (1 - oh[:, None, :, None]) + oh[:, None, :, None] * vT

        qg = q.reshape(b_loc, Hk, g, 1, cfg.d_head).astype(jnp.float32)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg,
                       kc.astype(jnp.float32)) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        col = col0 + jnp.arange(l_loc)[None, :]
        valid = col <= pos[:, None]
        if window is not None:
            valid &= col > pos[:, None] - window
        s = jnp.where(valid[:, None, None, None], s, -1e30)
        m_loc = jnp.max(s, axis=-1, keepdims=True)              # [B,Hk,g,1,1]
        p = jnp.where(s > -5e29, jnp.exp(s - m_loc), 0.0)
        l_sum = jnp.sum(p, axis=-1, keepdims=True)
        acc = jnp.einsum("bhgqk,bhkd->bhgqd", p, vc.astype(jnp.float32))

        # merge shards: tiny stat exchange instead of a KV all-gather
        m_all = jax.lax.all_gather(m_loc, tp)                   # [ntp,...]
        l_all = jax.lax.all_gather(l_sum, tp)
        a_all = jax.lax.all_gather(acc, tp)
        m_g = jnp.max(m_all, axis=0)
        w = jnp.exp(m_all - m_g[None])
        out = jnp.sum(a_all * w, axis=0) / jnp.maximum(
            jnp.sum(l_all * w, axis=0), 1e-30
        )
        out = out.reshape(b_loc, Hk * g, 1, cfg.d_head)
        return out.astype(kT.dtype), kc, vc

    # `out` IS replicated over tp (every shard computes the same merge from
    # the gathered stats) — the compat shim disables the static replication
    # checker, which can't see that
    out, kc, vc = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None, None),
            P(bspec, None, None, None),
            P(bspec, None, None, None),
            P(bspec, None, tp, None),
            P(bspec, None, tp, None),
            P(bspec),
        ),
        out_specs=(
            P(bspec, None, None, None),
            P(bspec, None, tp, None),
            P(bspec, None, tp, None),
        ),
    )(q, kT, vT, k_cache, v_cache, pos)
    out = jnp.swapaxes(out, 1, 2).reshape(B, 1, cfg.n_heads * cfg.d_head)
    return out, (kc, vc)
