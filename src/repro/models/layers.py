"""Shared model primitives: norms, rotary embeddings (incl. M-RoPE), SwiGLU.

Parameters are plain nested dicts of jnp arrays; every function is pure.
Initialization uses truncated-normal fan-in scaling.  Sharding is applied
from the *outside* by repro.dist (PartitionSpec trees pattern-matched on
param paths), keeping model code mesh-agnostic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

from repro.dist.act_sharding import shard_act


def trunc_normal(key, shape, scale: float | None = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 1 else 1
    if len(shape) >= 2:
        fan_in = 1
        for d in shape[:-1]:
            fan_in *= d
    std = scale if scale is not None else fan_in ** -0.5
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms_norm(d: int) -> Array:
    return jnp.zeros((d,), jnp.float32)  # stored as (scale - 1), gemma-style


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [B, S, H, Dh]; positions: [B, S] int32."""
    freqs = rope_freqs(x.shape[-1], theta)                       # [Dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs       # [B,S,Dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions3: Array, theta: float,
                sections: tuple[int, ...]) -> Array:
    """Multimodal RoPE (qwen2-vl): rotary dims split into (t, h, w) sections,
    each rotated by its own position stream.

    x: [B, S, H, Dh]; positions3: [3, B, S] (temporal, height, width).
    sections: half-dim sizes per stream, sum == Dh // 2.
    """
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                                # [Dh/2]
    # build per-dim position by section
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=dh // 2
    )                                                            # [Dh/2]
    pos = positions3.astype(jnp.float32)                         # [3,B,S]
    pos_per_dim = pos[sec_id]                                    # [Dh/2,B,S]
    ang = jnp.moveaxis(pos_per_dim, 0, -1) * freqs               # [B,S,Dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": trunc_normal(k1, (d_model, d_ff)),
        "w_up": trunc_normal(k2, (d_model, d_ff)),
        "w_down": trunc_normal(k3, (d_ff, d_model)),
    }


def mlp(params: dict, x: Array) -> Array:
    dt = x.dtype
    g = shard_act(x @ params["w_gate"].astype(dt), ("batch", None, "model"))
    u = shard_act(x @ params["w_up"].astype(dt), ("batch", None, "model"))
    return (jax.nn.silu(g) * u) @ params["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d_model: int) -> Array:
    return trunc_normal(key, (vocab, d_model), scale=1.0)


def embed(table: Array, tokens: Array, dtype) -> Array:
    return table.astype(dtype)[tokens]


def unembed(x: Array, table_or_head: Array, softcap: float = 0.0) -> Array:
    """x: [..., D] @ head [D, V] (or tied embed [V, D] transposed) -> logits."""
    w = table_or_head
    if w.shape[0] != x.shape[-1]:
        w = w.T                                                   # tied [V,D]
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits
