"""repro.models — the architecture zoo for the assigned pool.

Families: dense GQA (phi3/qwen3/gemma2/internlm2), MoE (qwen3-moe, granite),
SSM (mamba2), hybrid (jamba), encoder-decoder (whisper), VLM backbone
(qwen2-vl).  All share one pure-function parameter-dict style and one Model
API (api.build_model).
"""
from repro.models.api import Model, build_model
from repro.models.config import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    EncoderConfig,
    ModelConfig,
    MoEConfig,
    ShapeSpec,
    SSMConfig,
    shape_applicable,
)

__all__ = [
    "Model", "build_model",
    "ModelConfig", "MoEConfig", "SSMConfig", "EncoderConfig", "ShapeSpec",
    "ALL_SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "shape_applicable",
]
