"""Policy search over campaign grids: random search and successive halving.

A policy study is an optimization loop around ``run_campaign``: sample
candidate ``Policy`` / workload knobs, simulate each candidate as one row of
a stacked campaign, score a ``SimResult`` metric, and iterate.  What makes
this fast here is what every PR since PR 3 has protected: the knobs are
*traced*, so changing candidate values — or shrinking the population between
successive-halving rungs — re-enters the SAME compiled chunk program
(simlint R5 verifies the rung loop compiles exactly once; DESIGN.md §12).

Knob spaces are plain dicts ``{name: candidate values}``.  Names that are
``Policy`` dataclass fields are vmapped into ``template.policy``; anything
else (workload knobs such as MTBF) is routed to the caller's
``instantiate(template, extras, n, key)`` hook, which returns
``broadcast_campaign`` overrides — e.g. vmapped ``workload.host_outages``
schedules.  See examples/campaign_search.py for the end-to-end shape.

Successive halving keeps its compiled program fixed across rungs by
construction: scores scatter into a ``ValuesReducer`` with ``n_slots`` =
initial population, the chunk size never changes (smaller rung populations
pad to the same chunk shape), and the per-rung fidelity (default: the traced
``policy.horizon``) rides as data.  Survivor selection is a host-side
argsort of the rung's score table.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.campaign import broadcast_campaign, run_campaign
from repro.core.entities import Policy, Scenario
from repro.core.reducers import ValuesReducer

_POLICY_FIELDS = frozenset(f.name for f in dataclasses.fields(Policy))


def grid_params(space: dict) -> dict:
    """Full cartesian product of a knob space -> ``{name: [prod] array}``.

    The exhaustive counterpart to ``sample_params``: a
    ``{mtbf: 4, ckpt: 4, migration: 2}`` space becomes 32 aligned candidate
    rows, ready for ``build_campaign`` / ``run_campaign``.
    """
    if not space:
        raise ValueError("empty search space")
    names = tuple(space)
    axes = [jnp.asarray(space[k]) for k in names]
    grids = jnp.meshgrid(*axes, indexing="ij")
    return {k: g.reshape(-1) for k, g in zip(names, grids)}


def sample_params(key, space: dict, n: int) -> dict:
    """Sample ``n`` candidates uniformly from each knob's value list.

    Independent per-knob draws (random search in the grid's support): for
    the high-dimensional spaces where exhaustive grids explode, uniform
    random candidates cover each marginal at the same density.
    """
    if not space:
        raise ValueError("empty search space")
    params = {}
    for sub, (name, vals) in zip(
        jax.random.split(key, len(space)), sorted(space.items())
    ):
        vals = jnp.asarray(vals)
        idx = jax.random.randint(sub, (n,), 0, vals.shape[0])
        params[name] = vals[idx]
    return params


def build_campaign(template: Scenario, params: dict, *,
                   instantiate=None, key=None) -> Scenario:
    """Candidate table -> stacked campaign.

    ``params`` maps knob names to aligned ``[n]`` value arrays.  ``Policy``
    field names are vmap-substituted into ``template.policy``; the rest are
    handed to ``instantiate(template, extras, n, key)`` which must return a
    dict of ``broadcast_campaign`` overrides (e.g. a vmapped ``outages=``
    schedule built from an ``mtbf_s`` column).
    """
    n = int(jnp.shape(next(iter(params.values())))[0])
    pol_kw = {k: jnp.asarray(v) for k, v in params.items()
              if k in _POLICY_FIELDS}
    extras = {k: jnp.asarray(v) for k, v in params.items()
              if k not in _POLICY_FIELDS}
    overrides = {}
    if pol_kw:
        overrides["policy"] = jax.vmap(
            lambda kw: template.policy.replace(**kw)
        )(pol_kw)
    if extras:
        if instantiate is None:
            raise ValueError(
                f"knobs {sorted(extras)} are not Policy fields; pass "
                "instantiate=(template, extras, n, key) -> overrides to "
                "build their scenario subtrees"
            )
        more = instantiate(template, extras, n, key)
        overlap = set(more) & set(overrides)
        if overlap:
            raise ValueError(f"instantiate returned {sorted(overlap)}, "
                             "already produced from Policy knobs")
        overrides.update(more)
    return broadcast_campaign(template, n, **overrides)


def _take(params: dict, idx) -> dict:
    return {k: v[idx] for k, v in params.items()}


def random_search(template: Scenario, space: dict, *, key, n: int,
                  metric="total_cost", mode: str = "min",
                  chunk_size: int | None = None, mesh=None,
                  axis: str = "data", instantiate=None) -> dict:
    """Score ``n`` uniformly-sampled candidates in one streamed campaign.

    Returns ``{"params", "values", "best_params", "best_value",
    "best_index"}`` — the full candidate table plus its scores, never the
    ``[n, ...]`` results.  ``chunk_size``/``mesh`` stream and shard exactly
    as in ``run_campaign``.
    """
    k_sample, k_inst = jax.random.split(key)
    params = sample_params(k_sample, space, n)
    batched = build_campaign(template, params,
                             instantiate=instantiate, key=k_inst)
    out = run_campaign(batched, chunk_size=chunk_size, mesh=mesh, axis=axis,
                       reduce=ValuesReducer(metric, n_slots=n))
    values = out["values"]
    sign = 1.0 if mode == "min" else -1.0
    best = int(jnp.argmin(sign * values))
    return {"params": params, "values": values,
            "best_params": _take(params, best),
            "best_value": values[best], "best_index": best}


def successive_halving(template: Scenario, space: dict, *, key, n0: int,
                       fidelities, eta: int = 2, metric="total_cost",
                       mode: str = "min", fidelity_knob: str = "horizon",
                       chunk_size: int | None = None, mesh=None,
                       axis: str = "data", instantiate=None) -> dict:
    """Successive halving: evaluate everyone cheaply, promote the top
    ``1/eta`` to the next (more expensive) fidelity, repeat.

    ``fidelities`` gives ``fidelity_knob`` (a traced ``Policy`` field;
    default the simulation ``horizon``, which bounds the event loop) one
    value per rung, cheapest first.  Every rung re-enters ONE compiled
    chunk program: the score table is a fixed ``n_slots=n0``
    ``ValuesReducer``, the chunk size is pinned to ``chunk_size or n0`` (a
    shrinking population pads back up to it), and both the candidate knobs
    and the fidelity ride as traced data — so rung 3's 8 survivors at full
    horizon hit the jit cache warmed by rung 0's 64 candidates at 1/8
    horizon (simlint R5 probes exactly this).

    Returns ``{"params", "best_params", "best_value", "best_index",
    "rungs"}``: the full ``[n0]`` candidate table, the winner, and per-rung
    ``{fidelity, candidates, values}`` records (``candidates`` = surviving
    global candidate indices into ``params``, for frontier summaries).
    """
    if fidelity_knob not in _POLICY_FIELDS:
        raise ValueError(f"fidelity knob {fidelity_knob!r} is not a Policy "
                         "field (must be traced to avoid recompiles)")
    if fidelity_knob in space:
        raise ValueError(f"fidelity knob {fidelity_knob!r} cannot also be "
                         "a search dimension")
    if n0 < eta ** (len(tuple(fidelities)) - 1):
        raise ValueError(f"n0={n0} cannot halve {len(tuple(fidelities)) - 1}"
                         f" times by eta={eta}")
    k_sample, k_inst = jax.random.split(key)
    params = sample_params(k_sample, space, n0)
    chunk = chunk_size or n0
    reducer = ValuesReducer(metric, n_slots=n0)
    sign = 1.0 if mode == "min" else -1.0

    alive = jnp.arange(n0)
    rungs = []
    for fid in fidelities:
        cand = _take(params, alive)
        cand[fidelity_knob] = jnp.full(
            (alive.shape[0],), fid,
            dtype=getattr(template.policy, fidelity_knob).dtype,
        )
        batched = build_campaign(template, cand,
                                 instantiate=instantiate, key=k_inst)
        out = run_campaign(batched, chunk_size=chunk, mesh=mesh, axis=axis,
                           reduce=reducer)
        values = out["values"][: alive.shape[0]]
        rungs.append({"fidelity": fid, "candidates": alive,
                      "values": values})
        order = jnp.argsort(sign * values)
        keep = max(alive.shape[0] // eta, 1)
        alive = alive[order[:keep]]
    best = int(alive[0])
    return {"params": params,
            "best_params": _take(params, best),
            "best_value": rungs[-1]["values"][int(jnp.argmin(
                sign * rungs[-1]["values"]))],
            "best_index": best, "rungs": rungs}
