"""KV-cache-bound continuous batching: the LLM-serving phase (DESIGN.md §14).

A serving cloudlet (``Cloudlets.prompt_tokens > 0``) is a token-generation
request: its ``length_mi`` is ``max_new_tokens`` decode steps of
``length_mi / max_new_tokens`` MI each, and while it decodes it holds
KV-cache blocks of its VM's pool (``VMRequests.kv_blocks``, reserved on the
host via the ordinary provisioning ledger — ``Hosts.kv_blocks`` is the
capacity dimension).  The phase below is the vLLM-style block scheduler
re-derived as dataflow, run once per event behind a scalar ``lax.cond``
(``step.SCOPE_SERVING``):

1. **release** — finished rows give their blocks back to the VM pool.
2. **growth commit** — an admitted row's footprint is recomputed from its
   context length: every filled block plus the open block its next token
   writes into (paged-attention semantics).
3. **eviction** — if a VM's committed footprints exceed its pool, the
   *youngest* residents (highest row index — rows are submit-ordered) are
   preempted until the rest fit.  A preempted request loses its cache and
   rolls back to its last completed token (the delta lands in
   ``cl_rollback_mi``, the PR-5 re-done-work meter); it re-enters admission
   as an ordinary waiting row.
4. **admission** — ready, waiting serving rows are admitted FCFS (row
   order) while their prefill footprint fits the pool's free blocks.

Only admitted rows make progress: ``policies.cloudlet_rates`` grants them
the continuous-batch decode rate ``percore / (1 + alpha * (b - 1))`` and
gives waiting rows zero.  ``serving_bound`` contributes the next
block-boundary crossing as a clock stop (``step.K_SERVING``), so growth —
and therefore eviction — lands on exact block edges.

Every write is gated on the serving mask, so scenarios without serving rows
are bitwise untouched (the phase is skipped entirely by its cond).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import Array

from repro.core import policies, segments
from repro.core.entities import INF, Scenario, SimState

# Token-count comparisons tolerate 0.1 token of float32 drift: the work
# counters drift ~step._eps_mi per event, which at per-token MI of O(10)
# is a few hundredths of a token.
TOKEN_EPS = 0.1


def is_serving(scn: Scenario) -> Array:
    """[C] bool — existing token-generation (serving) rows."""
    cls = scn.cloudlets
    return cls.exists & (cls.prompt_tokens > 0.0)


def token_mi(scn: Scenario) -> Array:
    """[C] MI per decode step (per generated token)."""
    cls = scn.cloudlets
    return cls.length_mi / jnp.maximum(cls.max_new_tokens, 1.0)


def generated_tokens(scn: Scenario, state: SimState) -> Array:
    """[C] tokens emitted so far (fractional between boundary events)."""
    cls = scn.cloudlets
    g = (cls.length_mi - state.rem_mi) / jnp.maximum(token_mi(scn), 1e-9)
    return jnp.clip(g, 0.0, cls.max_new_tokens)


def context_tokens(scn: Scenario, state: SimState) -> Array:
    """[C] current context length: prompt plus generated tokens."""
    return scn.cloudlets.prompt_tokens + generated_tokens(scn, state)


def blocks_needed(scn: Scenario, state: SimState) -> Array:
    """[C] KV blocks a serving row needs right now: every block its context
    has filled plus the open block its next token writes into."""
    bt = jnp.maximum(scn.policy.block_tokens, 1.0)
    ctx = context_tokens(scn, state)
    return jnp.where(
        is_serving(scn), jnp.floor((ctx + TOKEN_EPS) / bt) + 1.0, 0.0
    )


def serving_needed(scn: Scenario, state: SimState) -> Array:
    """Scalar bool — the scenario carries serving rows at all.  The phase's
    skip predicate: non-serving scenarios never pay for the ledger sweep
    (and stay bitwise identical to the pre-serving engine)."""
    return jnp.any(is_serving(scn))


def serving_phase(scn: Scenario, state: SimState) -> SimState:
    """One KV-block ledger sweep: release, growth commit, eviction,
    admission (module docstring).  Pure; exact identity when the scenario
    has no serving rows."""
    cls, vms = scn.cloudlets, scn.vms
    V = vms.n_vms
    srv = is_serving(scn)
    vmi = jnp.clip(state.cl_vm, 0, V - 1)
    fin = policies.cloudlet_finished(state)
    need = blocks_needed(scn, state)

    # 1 + 2: finished rows release; admitted rows commit context growth.
    admitted = state.cl_admitted & ~fin
    cl_kv = jnp.where(admitted, need, 0.0)

    # 3: per-VM overflow -> evict youngest-first until the rest fit.  A row
    # is evicted iff the rows *after* it (strictly younger) do not cover the
    # overflow on their own — the minimal youngest suffix.
    seg = jnp.where(admitted, vmi, V)
    blocks = jnp.where(admitted, cl_kv, 0.0)
    usage = segments.segment_sum(blocks, seg, V)                     # [V]
    over = jnp.maximum(usage - vms.kv_blocks, 0.0)                   # [V]
    prefix = segments.segment_prefix_sum(blocks, seg, V)             # excl
    younger = usage[vmi] - (prefix + blocks)     # blocks of strictly-later rows
    evict = admitted & (younger < over[vmi] - 1e-6)

    # A preempted request loses its KV cache: work past the last completed
    # token is re-done (PR-5 rollback meter), and the row re-enters
    # admission as an ordinary waiting candidate (at the *next* event — no
    # same-event evict/re-admit churn).
    tok = token_mi(scn)
    g_keep = jnp.floor(generated_tokens(scn, state) + TOKEN_EPS)
    executed = cls.length_mi - state.rem_mi
    kept = jnp.minimum(g_keep * tok, executed)
    new_rem = jnp.where(evict, cls.length_mi - kept, state.rem_mi)

    admitted = admitted & ~evict
    cl_kv = jnp.where(evict, 0.0, cl_kv)
    usage = usage - segments.segment_sum(
        jnp.where(evict, blocks, 0.0), seg, V
    )

    # 4: FCFS admission (row order == submit order) among ready waiting
    # rows whose VM is placed and booted; each admits iff the pool still
    # fits it after everyone ahead of it in the queue.
    ready = policies.cloudlet_ready(scn, state)
    cand = (
        srv & ~fin & ~admitted & ~evict & ready
        & (state.cl_vm >= 0) & state.vm_placed[vmi]
        & (state.t >= state.vm_avail_t[vmi])
    )
    seg_c = jnp.where(cand, vmi, V)
    need_c = jnp.where(cand, need, 0.0)
    prefix_c = segments.segment_prefix_sum(need_c, seg_c, V)
    admit = cand & (
        usage[vmi] + prefix_c + need <= vms.kv_blocks[vmi] + 1e-6
    )
    admitted = admitted | admit
    cl_kv = jnp.where(admit, need, cl_kv)

    return state.replace(
        cl_admitted=admitted,
        cl_kv=cl_kv,
        rem_mi=new_rem,
        cl_rollback_mi=state.cl_rollback_mi + (new_rem - state.rem_mi),
    )


def serving_bound(scn: Scenario, state: SimState, rate: Array) -> Array:
    """Scalar next-event bound: the earliest block-boundary crossing among
    decoding rows.  Strictly future (``blocks_needed`` already counts a
    boundary within TOKEN_EPS as crossed, so the next edge is at least a
    full block — minus drift — away); INF when nothing decodes."""
    fin = policies.cloudlet_finished(state)
    occ = is_serving(scn) & state.cl_admitted & ~fin & (rate > 0)
    bt = jnp.maximum(scn.policy.block_tokens, 1.0)
    ctx = context_tokens(scn, state)
    nxt = (jnp.floor((ctx + TOKEN_EPS) / bt) + 1.0) * bt
    to_go = jnp.maximum(nxt - ctx, 0.0)
    t_cross = state.t + to_go * token_mi(scn) / jnp.maximum(rate, 1e-9)
    return jnp.min(jnp.where(occ, t_cross, INF), initial=INF)
