"""Frozen-dataclass pytrees.

Every simulator structure is a struct-of-arrays pytree: entity *count* is a
shape (static), entity *state* is data (traced).  This is the tensorized form
of CloudSim's "minimize the number of entities" design (paper §4.1): the paper
reduced N Java threads to 2; here entities are rows of arrays and the engine
is a single dataflow program, so the scheduler overhead per entity is zero.
"""
from __future__ import annotations

import dataclasses
from typing import TypeVar

import jax

_T = TypeVar("_T")


def pytree_dataclass(cls: type | None = None, *, static: tuple[str, ...] = ()):
    """Register a frozen dataclass as a JAX pytree.

    Fields named in ``static`` become metadata (hashed into the jit cache key);
    everything else is traced array data.
    """

    def wrap(c: type) -> type:
        c = dataclasses.dataclass(frozen=True)(c)
        names = [f.name for f in dataclasses.fields(c)]
        for s in static:
            if s not in names:
                raise ValueError(f"static field {s!r} not a field of {c.__name__}")
        data = [n for n in names if n not in static]
        jax.tree_util.register_dataclass(c, data_fields=data, meta_fields=list(static))
        c.replace = dataclasses.replace  # ergonomic immutable update
        return c

    return wrap(cls) if cls is not None else wrap
