"""Seeded dynamic-workload generators (the paper's "varying load").

The abstract promises simulation of *varying load* and *automatic
application scaling*; CloudSim's companion paper (arXiv:0903.2525) makes
dynamic workload generation a first-class feature.  This module provides the
arrival-process grammar (DESIGN.md §7):

* ``poisson_arrivals``  — homogeneous Poisson: iid exponential gaps.
* ``diurnal_arrivals``  — sinusoid-modulated non-homogeneous Poisson via
                          time-rescaling: unit-rate arrivals pushed through
                          the inverse cumulative intensity Λ⁻¹ (bisection —
                          fixed iteration count, so jit/vmap-safe).
* ``bursty_arrivals``   — on/off bursts: exponential off-gaps between bursts,
                          within-burst gaps at ``burst_rate``.
* ``host_outages``      — per-host failure/repair schedules (exponential
                          MTBF/MTTR), the reliability subsystem's input
                          (DESIGN.md §9).

Everything is a pure function of a ``jax.random`` key with **static shapes**
(the arrival *count* is the shape; the *times* are traced), so campaigns
vmap over seeds and over traced rate/shape parameters in one compilation —
same key ⇒ bit-identical workload (tests/test_workload.py).

``generate_cloudlets`` assembles a full ``Cloudlets`` table: arrivals plus
lognormal lengths and IO sizes, routed either round-robin over a fixed VM
fleet or *service-routed* (``vm == -1``: the broker dispatches each arrival
to the least-loaded active VM — the binding auto-scaling acts through).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.entities import INF, Cloudlets, Outages

_TWO_PI = 6.2831853


def host_outages(
    key: Array, n_dc: int, n_hosts: int, n_outages: int, mtbf_s, mttr_s
) -> Outages:
    """``[D, H, K]`` seeded exponential failure/repair schedule (DESIGN.md §9).

    Up-gaps ~ Exp(mean ``mtbf_s``) and down-durations ~ Exp(mean ``mttr_s``)
    alternate, so ``fail_t[k] = Σ_{i<=k} gap_i + Σ_{i<k} dur_i`` and
    ``repair_t[k] = fail_t[k] + dur_k`` — windows are disjoint and sorted by
    construction.  Shapes are static (``n_outages`` bounds failures per
    host); everything else is traced, so campaigns vmap over
    ``(key, mtbf, mttr)`` grids exactly like the arrival generators.
    ``mtbf_s`` / ``mttr_s`` may be scalars or ``[D, H]`` arrays (per-host
    reliability classes); ``mtbf_s >= INF`` pushes every failure past the
    horizon — the static control with identical shapes, hence the same
    compiled program as its failing peers.
    """
    k_up, k_down = jax.random.split(key)
    shape = (n_dc, n_hosts, n_outages)
    mtbf = jnp.broadcast_to(
        jnp.asarray(mtbf_s, jnp.float32), (n_dc, n_hosts))[..., None]
    # durations must stay finite: fail_t = cumsum(gaps) + excl-cumsum(durs)
    # would go NaN on inf - inf otherwise
    mttr = jnp.clip(
        jnp.broadcast_to(jnp.asarray(mttr_s, jnp.float32),
                         (n_dc, n_hosts))[..., None],
        1e-6, 1e30)
    gaps = jax.random.exponential(k_up, shape, jnp.float32) * mtbf
    durs = jax.random.exponential(k_down, shape, jnp.float32) * mttr
    cum_durs = jnp.cumsum(durs, axis=-1)
    fail = jnp.cumsum(gaps, axis=-1) + (cum_durs - durs)
    # mtbf >= INF means *never*, exactly: a sub-1 exponential draw times INF
    # would otherwise land short of the padding sentinel
    never = jnp.broadcast_to(mtbf >= INF / 2, shape)
    return Outages(
        fail_t=jnp.where(never, INF, jnp.minimum(fail, INF)),
        repair_t=jnp.where(never, INF, jnp.minimum(fail + durs, INF)),
    )


def no_outages(n_dc: int, n_hosts: int, n_outages: int = 1) -> Outages:
    """An all-INF schedule: hosts never fail, but the ``Outages`` attachment
    (and so the compiled program) matches a failing campaign row's."""
    shape = (n_dc, n_hosts, n_outages)
    return Outages(
        fail_t=jnp.full(shape, INF, jnp.float32),
        repair_t=jnp.full(shape, INF, jnp.float32),
    )


def poisson_arrivals(key: Array, n: int, rate) -> Array:
    """[n] sorted arrival times of a homogeneous Poisson process."""
    rate = jnp.maximum(jnp.asarray(rate, jnp.float32), 1e-9)
    gaps = jax.random.exponential(key, (n,), jnp.float32) / rate
    return jnp.cumsum(gaps)


def diurnal_arrivals(
    key: Array, n: int, base_rate, amp=0.8, period=1000.0, iters: int = 60
) -> Array:
    """[n] arrivals of a non-homogeneous Poisson process with intensity
    ``λ(t) = base_rate · (1 + amp·sin(2πt/period))``, ``0 <= amp < 1``.

    Time-rescaling: if S_k are unit-rate Poisson arrivals, Λ⁻¹(S_k) has
    intensity λ.  Λ is monotone, so Λ⁻¹ is a fixed-count vectorized
    bisection — no data-dependent control flow, vmappable over traced
    ``base_rate``/``amp``/``period``.
    """
    base = jnp.maximum(jnp.asarray(base_rate, jnp.float32), 1e-9)
    amp = jnp.clip(jnp.asarray(amp, jnp.float32), 0.0, 0.999)
    period = jnp.maximum(jnp.asarray(period, jnp.float32), 1e-6)
    s = jnp.cumsum(jax.random.exponential(key, (n,), jnp.float32))

    def cum_intensity(t):
        osc = (1.0 - jnp.cos(_TWO_PI * t / period)) * period / _TWO_PI
        return base * (t + amp * osc)

    # Λ(t) >= base·(1-amp)·t bounds the search interval from above.
    lo = jnp.zeros_like(s)
    hi = jnp.broadcast_to(s[-1] / (base * (1.0 - amp)) + period, s.shape)

    def bisect(_, lo_hi):
        lo, hi = lo_hi
        mid = 0.5 * (lo + hi)
        below = cum_intensity(mid) < s
        return jnp.where(below, mid, lo), jnp.where(below, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, bisect, (lo, hi))
    return 0.5 * (lo + hi)


def bursty_arrivals(
    key: Array, n_bursts: int, per_burst: int, burst_rate, off_gap_mean
) -> Array:
    """[n_bursts·per_burst] on/off arrivals: bursts of ``per_burst`` jobs at
    ``burst_rate`` separated by exponential off-gaps of mean ``off_gap_mean``.

    Built as cumulative (gap, burst-duration) sums, so the output is sorted
    by construction and every quantity stays traced.
    """
    k_gap, k_in = jax.random.split(key)
    rate = jnp.maximum(jnp.asarray(burst_rate, jnp.float32), 1e-9)
    off = jnp.maximum(jnp.asarray(off_gap_mean, jnp.float32), 0.0)
    gaps = jax.random.exponential(k_gap, (n_bursts,), jnp.float32) * off
    intra = jax.random.exponential(
        k_in, (n_bursts, per_burst), jnp.float32) / rate
    within = jnp.cumsum(intra, axis=1)                  # offsets inside a burst
    dur = within[:, -1]
    starts = jnp.cumsum(gaps) + jnp.concatenate(
        [jnp.zeros((1,), jnp.float32), jnp.cumsum(dur)[:-1]]
    )
    return (starts[:, None] + within).reshape(-1)


def lognormal(key: Array, n: int, median, sigma) -> Array:
    """[n] lognormal samples with the given median and log-space sigma."""
    med = jnp.asarray(median, jnp.float32)
    return med * jnp.exp(
        jnp.asarray(sigma, jnp.float32) * jax.random.normal(key, (n,), jnp.float32)
    )


def assemble_cloudlets(
    vm: Array, length_mi: Array, submit_t: Array,
    cores=1, input_mb=0.0, output_mb=0.0, deadline=INF, input_dc=-1,
    prompt_tokens=0.0, max_new_tokens=0.0,
) -> Cloudlets:
    """Traced twin of ``scenarios.make_cloudlets``: jnp sort by submit time
    (FCFS is row order downstream), everything vmappable.  ``deadline`` is
    the absolute SLA finish time (INF: none); ``input_dc >= 0`` declares the
    datacenter holding the row's input data (stage-in becomes a network
    transfer, DESIGN.md §13); ``prompt_tokens > 0`` marks a serving row
    generating ``max_new_tokens`` tokens against a KV-block budget
    (DESIGN.md §14)."""
    n = submit_t.shape[0]
    order = jnp.argsort(submit_t, stable=True)
    bcast = lambda x, dt: jnp.broadcast_to(jnp.asarray(x, dt), (n,))[order]
    return Cloudlets(
        vm=bcast(vm, jnp.int32),
        length_mi=bcast(length_mi, jnp.float32),
        cores=bcast(cores, jnp.int32),
        submit_t=jnp.asarray(submit_t, jnp.float32)[order],
        input_mb=bcast(input_mb, jnp.float32),
        input_dc=bcast(input_dc, jnp.int32),
        output_mb=bcast(output_mb, jnp.float32),
        deadline=bcast(deadline, jnp.float32),
        prompt_tokens=bcast(prompt_tokens, jnp.float32),
        max_new_tokens=bcast(max_new_tokens, jnp.float32),
        exists=jnp.ones((n,), bool),
    )


def generate_cloudlets(
    key: Array,
    n: int,
    *,
    kind: str = "poisson",
    rate=1.0,
    amp=0.8,
    period=1000.0,
    n_bursts: int = 4,
    off_gap_mean=500.0,
    median_mi=10_000.0,
    sigma_mi=0.5,
    io_mb=0.0,
    sigma_io=0.5,
    n_vms: int | None = None,
    cores: int = 1,
    deadline_rel=None,
) -> Cloudlets:
    """One seeded dynamic workload -> a ``Cloudlets`` table.

    ``kind``/``n``/``n_bursts``/``n_vms`` are static (shapes and routing
    structure); every other parameter is traced, so campaigns vmap over
    ``(key, rate, …)`` grids.  ``n_vms=None`` emits service-routed rows
    (``vm == -1``, broker-dispatched); an int routes round-robin over that
    fleet.  For ``kind="bursty"``, ``n`` must divide into ``n_bursts`` and
    ``rate`` is the within-burst rate.  ``deadline_rel`` (traced, seconds
    after submission) attaches a per-cloudlet SLA deadline; None leaves the
    rows unguaranteed (deadline = INF).
    """
    k_arr, k_len, k_in, k_out = jax.random.split(key, 4)
    if kind == "poisson":
        submit = poisson_arrivals(k_arr, n, rate)
    elif kind == "diurnal":
        submit = diurnal_arrivals(k_arr, n, rate, amp=amp, period=period)
    elif kind == "bursty":
        if n % n_bursts:
            raise ValueError(f"n={n} not divisible by n_bursts={n_bursts}")
        submit = bursty_arrivals(
            k_arr, n_bursts, n // n_bursts, rate, off_gap_mean)
    else:
        raise ValueError(f"unknown arrival kind {kind!r}")

    length = lognormal(k_len, n, median_mi, sigma_mi)
    io_scale = jnp.asarray(io_mb, jnp.float32)
    input_mb = io_scale * jnp.exp(
        jnp.asarray(sigma_io, jnp.float32)
        * jax.random.normal(k_in, (n,), jnp.float32))
    output_mb = io_scale * jnp.exp(
        jnp.asarray(sigma_io, jnp.float32)
        * jax.random.normal(k_out, (n,), jnp.float32))
    vm = (
        jnp.full((n,), -1, jnp.int32) if n_vms is None
        else jnp.arange(n, dtype=jnp.int32) % n_vms
    )
    deadline = (
        INF if deadline_rel is None
        else submit + jnp.asarray(deadline_rel, jnp.float32)
    )
    return assemble_cloudlets(
        vm, length, submit, cores=cores, input_mb=input_mb,
        output_mb=output_mb, deadline=deadline,
    )


def generate_serving_requests(
    key: Array,
    n: int,
    *,
    kind: str = "diurnal",
    rate=1.0,
    amp=0.8,
    period=1000.0,
    n_bursts: int = 4,
    off_gap_mean=500.0,
    median_prompt=128.0,
    sigma_prompt=0.7,
    median_new=64.0,
    sigma_new=0.6,
    max_new_cap=1024.0,
    token_mi=10.0,
    sigma_token=0.2,
    deadline_rel=None,
) -> Cloudlets:
    """One seeded LLM-inference request stream -> serving ``Cloudlets``
    (DESIGN.md §14).

    Arrivals reuse the §7 grammar (``kind`` = poisson/diurnal/bursty at
    ``rate`` requests/s); prompt and decode lengths are lognormal token
    counts (rounded up to whole tokens, decode clipped to ``max_new_cap``),
    and each request's per-token service cost is ``token_mi`` MI jittered by
    ``sigma_token`` in log space — so ``length_mi = max_new_tokens x
    per-token MI`` and the engine recovers the per-token cost exactly.
    All distribution parameters are traced: a campaign vmaps
    ``(key, rate, median_prompt, ...)`` grids through one compilation.
    Rows are service-routed (``vm == -1``): the broker dispatches each
    arrival to the least-loaded serving replica, which is how the
    autoscaler's pool replicas absorb traffic.
    """
    k_arr, k_prompt, k_new, k_tok = jax.random.split(key, 4)
    if kind == "poisson":
        submit = poisson_arrivals(k_arr, n, rate)
    elif kind == "diurnal":
        submit = diurnal_arrivals(k_arr, n, rate, amp=amp, period=period)
    elif kind == "bursty":
        if n % n_bursts:
            raise ValueError(f"n={n} not divisible by n_bursts={n_bursts}")
        submit = bursty_arrivals(
            k_arr, n_bursts, n // n_bursts, rate, off_gap_mean)
    else:
        raise ValueError(f"unknown arrival kind {kind!r}")

    prompt = jnp.maximum(
        jnp.ceil(lognormal(k_prompt, n, median_prompt, sigma_prompt)), 1.0)
    new = jnp.clip(
        jnp.ceil(lognormal(k_new, n, median_new, sigma_new)),
        1.0, jnp.asarray(max_new_cap, jnp.float32))
    per_token = lognormal(k_tok, n, token_mi, sigma_token)
    deadline = (
        INF if deadline_rel is None
        else submit + jnp.asarray(deadline_rel, jnp.float32)
    )
    return assemble_cloudlets(
        jnp.full((n,), -1, jnp.int32), new * per_token, submit,
        deadline=deadline, prompt_tokens=prompt, max_new_tokens=new,
    )
