"""repro.core — the paper's contribution: a tensorized CloudSim.

Discrete-event simulation of virtualized datacenters (Datacenter -> Host ->
VM -> Cloudlet) with two-level space/time-shared scheduling, FCFS/best-fit VM
provisioning, federation with sensor-driven migration, and market accounting
— as one pure, jittable, vmappable JAX program (see DESIGN.md).
"""
from repro.core.entities import (
    INF,
    SPACE_SHARED,
    TIME_SHARED,
    Cloudlets,
    Hosts,
    Market,
    Policy,
    Scenario,
    SimResult,
    SimState,
    VMRequests,
    finished_mask,
)
from repro.core.engine import init_state, simulate, simulate_trace
from repro.core.campaign import run_campaign, run_campaign_sharded, stack_scenarios
from repro.core import energy, policies, provision, scenarios, segments

__all__ = [
    "INF", "SPACE_SHARED", "TIME_SHARED",
    "Cloudlets", "Hosts", "Market", "Policy", "Scenario",
    "SimResult", "SimState", "VMRequests", "finished_mask",
    "init_state", "simulate", "simulate_trace",
    "run_campaign", "run_campaign_sharded", "stack_scenarios",
    "energy", "policies", "provision", "scenarios", "segments",
]
