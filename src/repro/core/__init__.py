"""repro.core — the paper's contribution: a tensorized CloudSim.

Discrete-event simulation of virtualized datacenters (Datacenter -> Host ->
VM -> Cloudlet) with two-level space/time-shared scheduling, FCFS/best-fit VM
provisioning, federation with sensor-driven migration, and market accounting
— as one pure, jittable, vmappable JAX program (see DESIGN.md).

The event-loop body lives exactly once (``step.event_step``); ``simulate``,
``simulate_trace`` and ``simulate_history`` are thin drivers over it, and
cross-cutting observables (energy, market accrual, federation sensing, trace
sampling, …) are composable ``step.Instrument``s.
"""
from repro.core.entities import (
    INF,
    SPACE_SHARED,
    TIME_SHARED,
    Cloudlets,
    Hosts,
    Market,
    Outages,
    Policy,
    Scenario,
    SimResult,
    SimState,
    VMRequests,
    finished_mask,
)
from repro.core.engine import (
    History,
    init_state,
    is_batched,
    scenario_row,
    simulate,
    simulate_history,
    simulate_instrumented,
    simulate_trace,
)
from repro.core.step import (
    AutoscaleInstrument,
    Instrument,
    MigrationInstrument,
    ReliabilityInstrument,
    StepEvent,
    TraceInstrument,
    UtilizationTimelineInstrument,
    event_step,
)
from repro.core.campaign import (
    broadcast_campaign,
    run_campaign,
    run_campaign_sharded,
    stack_scenarios,
)
from repro.core.reducers import (
    ArgBestReducer,
    CampaignReducer,
    HistogramReducer,
    MeanReducer,
    SumReducer,
    ValuesReducer,
)
from repro.core import (
    energy,
    policies,
    provision,
    scenarios,
    search,
    segments,
    step,
    workload,
)

__all__ = [
    "INF", "SPACE_SHARED", "TIME_SHARED",
    "Cloudlets", "Hosts", "Market", "Outages", "Policy", "Scenario",
    "SimResult", "SimState", "VMRequests", "finished_mask",
    "AutoscaleInstrument", "History", "Instrument", "MigrationInstrument",
    "ReliabilityInstrument",
    "StepEvent", "TraceInstrument", "UtilizationTimelineInstrument",
    "init_state", "event_step", "is_batched", "scenario_row",
    "simulate", "simulate_history", "simulate_instrumented", "simulate_trace",
    "broadcast_campaign", "run_campaign", "run_campaign_sharded",
    "stack_scenarios",
    "ArgBestReducer", "CampaignReducer", "HistogramReducer", "MeanReducer",
    "SumReducer", "ValuesReducer",
    "energy", "policies", "provision", "scenarios", "search", "segments",
    "step", "workload",
]
