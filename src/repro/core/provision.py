"""VM provisioning + federated placement (paper §4 ``VMProvisioner``,
``BWProvisioner``/``MemoryProvisioner`` feasibility, §2.3/§5 federation).

``SimpleVMProvisioner`` semantics: VMs are considered in request order and
allocated to the first host that satisfies memory/storage/bandwidth (and,
optionally, core) requirements — "Hosts are considered for mapping in a
sequential order".  Sequential resource dependence makes this a ``lax.scan``
over VM rows carrying the free-capacity arrays.

Federation (the CloudCoordinator rule evaluated in the paper's Table 1):
a VM is placed in its origin datacenter if ANY host there fits; otherwise,
iff federation is enabled, it is migrated to the feasible peer datacenter
with the lowest *sensed* load (the Sensor refreshes periodically, so the
coordinator acts on possibly-stale information, as in the paper).  Migration
costs ``migration_fixed_s + image_mb / interdc_bw`` seconds before the VM
becomes usable, and the image transfer is billed at the destination's
bandwidth price.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.entities import INF, Scenario, SimState
from repro.core import policies


def _return_resources(scn: Scenario, state: SimState, newly: Array) -> SimState:
    """Give the host resources of ``newly``-masked VM rows back.

    Pure resource accounting: callers decide what the release *means*
    (terminal ``vm_released`` for drained VMs, back-to-inactive for pool
    rows, a slot handoff for live migration).
    """
    d = jnp.clip(state.vm_dc, 0, scn.hosts.n_dc - 1)
    h = jnp.clip(state.vm_host, 0, scn.hosts.n_hosts - 1)
    w = newly.astype(jnp.float32)
    return state.replace(
        free_ram=state.free_ram.at[d, h].add(w * scn.vms.ram_mb),
        free_storage=state.free_storage.at[d, h].add(w * scn.vms.storage_mb),
        free_bw=state.free_bw.at[d, h].add(w * scn.vms.bw_mbps),
        free_cores=state.free_cores.at[d, h].add(w * scn.vms.cores),
        free_kv=state.free_kv.at[d, h].add(w * scn.vms.kv_blocks),
    )


def release_done_vms(scn: Scenario, state: SimState) -> SimState:
    """Return resources of VMs whose entire workload finished (auto-destroy).

    Pool VMs are exempt: ``vm_done`` never reports them done, so the
    autoscaler's scale-down (``release_pool_vms``) is their sole destroyer.
    """
    done = policies.vm_done(scn, state)
    newly = done & state.vm_placed & ~state.vm_released
    state = _return_resources(scn, state, newly)
    return state.replace(vm_released=state.vm_released | newly)


def release_pool_vms(scn: Scenario, state: SimState, rel: Array) -> SimState:
    """Scale-down commit: release the ``rel``-masked pool VMs.

    The row returns to the *inactive* pool state (lifecycle inactive ->
    activating -> active -> inactive, DESIGN.md §7): host resources come
    back, placement is cleared, and the row is eligible for a later
    scale-up, which re-places it from its origin DC with the usual boot
    latency — the fixed-shape row is recycled, never re-allocated.
    """
    newly = rel & state.vm_placed & ~state.vm_released
    state = _return_resources(scn, state, newly)
    return state.replace(
        pool_active=state.pool_active & ~newly,
        vm_placed=state.vm_placed & ~newly,
        vm_host=jnp.where(newly, -1, state.vm_host),
        vm_dc=jnp.where(newly, scn.vms.dc, state.vm_dc),
        vm_avail_t=jnp.where(newly, INF, state.vm_avail_t),
        vm_mig_src=jnp.where(newly, -1, state.vm_mig_src),
    )


def apply_outages(scn: Scenario, state: SimState) -> SimState:
    """Commit host failure/repair transitions due at the current clock
    (``Scenario.outages`` schedule; the K_FAILURE/K_REPAIR clock stops in
    step.py land the loop exactly on the edges — DESIGN.md §9).

    **Failure** (host up, schedule says down): every resident VM — placed,
    not yet released — is *evicted*: placement cleared, pending-move marker
    reset, and its in-flight cloudlets roll back to the last completed
    ``Policy.ckpt_interval`` checkpoint (per-core MI; INF floors the kept
    work to zero — restart-from-zero).  The host's free ledger zeroes so
    nothing can land on it while down.  Eviction is the *transient*
    ``vm_evicted`` state, never the terminal ``vm_failed``: the row stays
    due, so ``provision_due_vms`` re-queues it through the ordinary creation
    path — a federation peer if one fits, the repaired host later otherwise
    — and it simply retries until capacity appears.

    **Repair** (host down, schedule says up): the host returns *empty* —
    free ledger restored to full capacity (its residents were evicted at the
    failure edge, so nothing holds resources on it).

    Also clears ``vm_evicted`` for VMs that are placed and available again,
    which stops the engine's downtime integral for them.
    """
    if scn.outages is None:
        return state
    hosts, vms, cls, pol = scn.hosts, scn.vms, scn.cloudlets, scn.policy
    down = scn.outages.down_at(state.t) & hosts.exists
    up_next = hosts.exists & ~down
    newly_down = state.host_up & down
    newly_up = ~state.host_up & up_next

    # recovered: re-placed and past its recovery transfer -> no longer down
    recovered = (
        state.vm_evicted & state.vm_placed & (state.vm_avail_t <= state.t)
    )

    d = jnp.clip(state.vm_dc, 0, hosts.n_dc - 1)
    h = jnp.clip(state.vm_host, 0, hosts.n_hosts - 1)
    evict = (
        vms.exists & state.vm_placed & ~state.vm_released & newly_down[d, h]
    )

    # checkpoint rollback: executed work floors to the last completed
    # ckpt_interval multiple; the delta is re-done work (cl_rollback_mi)
    cl_evict = (
        cls.exists & (state.cl_vm >= 0)
        & evict[jnp.clip(state.cl_vm, 0, vms.n_vms - 1)]
        & state.started & ~policies.cloudlet_finished(state)
    )
    executed = cls.length_mi - state.rem_mi
    ckpt = jnp.maximum(pol.ckpt_interval, 1e-6)
    kept = jnp.where(
        pol.ckpt_interval < INF / 2,
        jnp.minimum(jnp.floor(executed / ckpt) * ckpt, executed),
        0.0,
    )
    new_rem = jnp.where(cl_evict, cls.length_mi - kept, state.rem_mi)

    def ledger(free, capacity):
        return jnp.where(
            newly_down, 0.0, jnp.where(newly_up, capacity, free)
        )

    return state.replace(
        host_up=up_next,
        vm_placed=state.vm_placed & ~evict,
        vm_host=jnp.where(evict, -1, state.vm_host),
        vm_dc=jnp.where(evict, vms.dc, state.vm_dc),
        vm_avail_t=jnp.where(evict, INF, state.vm_avail_t),
        vm_mig_src=jnp.where(evict, -1, state.vm_mig_src),
        vm_evicted=(state.vm_evicted & ~recovered) | evict,
        rem_mi=new_rem,
        cl_rollback_mi=state.cl_rollback_mi + (new_rem - state.rem_mi),
        # A failure wipes the host's accelerator memory: evicted serving rows
        # lose their KV blocks and re-admit (re-prefilling) once their VM is
        # re-placed (DESIGN.md §14).
        cl_admitted=state.cl_admitted & ~cl_evict,
        cl_kv=jnp.where(cl_evict, 0.0, state.cl_kv),
        free_ram=ledger(state.free_ram, hosts.ram_mb),
        free_storage=ledger(state.free_storage, hosts.storage_mb),
        free_bw=ledger(state.free_bw, hosts.bw_mbps),
        free_cores=ledger(
            state.free_cores, hosts.cores.astype(jnp.float32)),
        free_kv=ledger(state.free_kv, hosts.kv_blocks),
    )


def resource_feasible(scn: Scenario, state: SimState, v: Array) -> Array:
    """[D, H] hosts meeting RAM/storage/bandwidth for VM row ``v`` (no core
    check — that is the slot-vs-stack distinction, see ``slot_feasible``).
    A failed host (``host_up`` False) is never feasible."""
    hosts, vms = scn.hosts, scn.vms
    return (
        hosts.exists
        & state.host_up
        & (state.free_ram >= vms.ram_mb[v])
        & (state.free_storage >= vms.storage_mb[v])
        & (state.free_bw >= vms.bw_mbps[v])
        & (state.free_kv >= vms.kv_blocks[v])
    )


def slot_feasible(scn: Scenario, state: SimState, v: Array) -> Array:
    """[D, H] free VM slots (resources + unreserved cores) for row ``v``."""
    return resource_feasible(scn, state, v) & (
        state.free_cores >= scn.vms.cores[v]
    )


def dc_capacity_mips(scn: Scenario) -> Array:
    """[D] total core-MIPS capacity of each datacenter's existing hosts."""
    return jnp.sum(
        jnp.where(
            scn.hosts.exists,
            scn.hosts.cores.astype(jnp.float32) * scn.hosts.mips,
            0.0,
        ),
        axis=1,
    )


def provision_due_vms(scn: Scenario, state: SimState) -> tuple[SimState, Array]:
    """Attempt placement for every due, unplaced, unfailed VM request.

    Returns (state', n_placed_this_call).  One scan step per VM row; each step
    is a fully-vectorized feasibility test over the global [D, H] host table
    (the CIS registry view) followed by a two-stage lexicographic choice:
    datacenter first (origin, then least-sensed-load peer), host within it
    (first-fit row order, or best-fit by leftover RAM).
    """
    hosts, vms, pol = scn.hosts, scn.vms, scn.policy
    D, H = hosts.cores.shape

    def place_one(st: SimState, v: Array) -> tuple[SimState, Array]:
        # Pool rows are due only once the autoscaler activates them; regular
        # rows at their broker request time.
        due = (
            (vms.request_t[v] <= st.t)
            & (~vms.pool[v] | st.pool_active[v])
            & ~st.vm_placed[v]
            & ~st.vm_failed[v]
            & vms.exists[v]
        )
        feasible = resource_feasible(scn, st, v)
        # Phase 1 — free VM slot (unreserved cores). Phase 2 — stack onto an
        # already-busy host (time-sharing it); forbidden when the provisioner
        # is core-reserving, and never used for migration: the paper's rule
        # migrates "only if the origin data center does not have the requested
        # number of free VM slots available" — stacking happens at home.
        slot_ok = feasible & (st.free_cores >= vms.cores[v])
        stack_ok = feasible & ~pol.core_reserving
        origin = vms.dc[v]
        is_origin = jnp.arange(D) == origin
        dc_slot = jnp.any(slot_ok, axis=1)
        dc_stack = jnp.any(stack_ok, axis=1)
        # Rank: origin slot < peer slot (by sensed load, federation only)
        #       < origin stack. Sensed load is stale by design (Sensor ticks).
        # With a Topology attached, peers are additionally penalized by the
        # normalized inter-DC latency from the origin (locality-aware
        # coordinator — the paper's BRITE future work made operational).
        BIG = jnp.float32(1e9)
        peer_score = st.sensed_load
        if scn.topology is not None:
            # Normalize over *finite* latencies only: an INF entry marks a
            # disconnected link, and INF/INF would poison the whole dc_key
            # row with NaN (argmin then lands on the NaN, rejecting feasible
            # peers).  Disconnected peers get a flat worst-case penalty but
            # stay selectable as a last resort.
            lat = scn.topology.latency_s[origin]             # [D]
            lat_ok = jnp.isfinite(lat)
            lat_max = jnp.max(jnp.where(lat_ok, lat, 0.0))
            peer_score = peer_score + jnp.where(
                lat_ok, lat / jnp.maximum(lat_max, 1e-9), 2.0
            )
        dc_key = jnp.where(
            is_origin & dc_slot,
            0.0,
            jnp.where(
                dc_slot & pol.federation & ~is_origin,
                1.0 + peer_score + jnp.arange(D) * 1e-4,
                jnp.where(is_origin & dc_stack, 3.0, BIG),
            ),
        )
        dsel = jnp.argmin(dc_key)
        found = due & (dc_key[dsel] < BIG)
        use_slot = dc_slot[dsel]

        # Host choice: slots by first-fit (CloudSim SimpleVMProvisioner) or
        # best-fit; stacking is first-fit without a coordinator, least-loaded
        # (max free RAM) when the federation coordinator is active.
        cand = jnp.where(use_slot, slot_ok[dsel], stack_ok[dsel])
        slot_key = jnp.where(
            pol.best_fit,
            st.free_ram[dsel] - vms.ram_mb[v],                   # tightest fit
            jnp.arange(H, dtype=jnp.float32),                    # first fit
        )
        stack_key = jnp.where(
            pol.federation,
            -st.free_ram[dsel],                                  # least loaded
            jnp.arange(H, dtype=jnp.float32),                    # first fit
        )
        host_key = jnp.where(use_slot, slot_key, stack_key)
        host_key = jnp.where(cand, host_key, jnp.inf)
        hsel = jnp.argmin(host_key)

        migrated = found & (dsel != origin)
        w = found.astype(jnp.float32)
        # Guard the gather indices exactly as live_migrate does: with no
        # feasible peer, dsel is whatever argmin returned over an all-BIG
        # (or NaN-poisoned) key row — never index the topology with it.
        dsafe = jnp.where(found, dsel, 0)
        hsafe = jnp.where(found, hsel, 0)
        if scn.topology is not None:
            # The image draws fair-share bandwidth from the (origin, dsafe)
            # link ledger: an idle link grants full capacity (bitwise the old
            # uncontended divisor), a busy one splits it k+1 ways.  The
            # transfer phase re-times every transfer already on the link
            # (DESIGN.md §13).
            share0 = scn.topology.bw_mbps[origin, dsafe] / (
                st.link_busy[origin, dsafe] + 1
            ).astype(jnp.float32)
            delay = (
                pol.migration_fixed_s
                + scn.topology.latency_s[origin, dsafe]
                + vms.image_mb[v] / jnp.maximum(share0, 1e-6)
            )
        else:
            delay = pol.migration_fixed_s + vms.image_mb[v] / jnp.maximum(
                pol.interdc_bw_mbps, 1e-6
            )

        # Pool activations pay the usual fixed VM-creation latency (the image
        # must boot); ordinary rows are created instantly at home, as before.
        boot = jnp.where(vms.pool[v], pol.migration_fixed_s, 0.0)
        st = st.replace(
            vm_host=st.vm_host.at[v].set(jnp.where(found, hsel, st.vm_host[v])),
            vm_dc=st.vm_dc.at[v].set(jnp.where(found, dsel, st.vm_dc[v])),
            vm_placed=st.vm_placed.at[v].set(st.vm_placed[v] | found),
            # An ordinary request nothing can host is rejected terminally
            # (CloudSim semantics).  A failure-evicted row is NOT: it stays
            # transiently homeless (vm_evicted) and retries at every event
            # until capacity — possibly its repaired host — fits it.
            vm_failed=st.vm_failed.at[v].set(
                st.vm_failed[v] | (due & ~found & ~st.vm_evicted[v])),
            vm_avail_t=st.vm_avail_t.at[v].set(
                jnp.where(found,
                          st.t + boot + jnp.where(migrated, delay, 0.0),
                          st.vm_avail_t[v])
            ),
            vm_migrations=st.vm_migrations.at[v].add(migrated.astype(jnp.int32)),
            free_ram=st.free_ram.at[dsafe, hsafe].add(-w * vms.ram_mb[v]),
            free_storage=st.free_storage.at[dsafe, hsafe].add(
                -w * vms.storage_mb[v]
            ),
            free_bw=st.free_bw.at[dsafe, hsafe].add(-w * vms.bw_mbps[v]),
            free_cores=st.free_cores.at[dsafe, hsafe].add(-w * vms.cores[v]),
            free_kv=st.free_kv.at[dsafe, hsafe].add(-w * vms.kv_blocks[v]),
            # market: RAM + storage billed at creation (paper §3.3); the
            # migrated image transits the inter-DC link -> bandwidth bill.
            ram_cost=st.ram_cost.at[dsafe].add(
                w * vms.ram_mb[v] * scn.market.cost_per_ram_mb[dsafe]
            ),
            storage_cost=st.storage_cost.at[dsafe].add(
                w * vms.storage_mb[v] * scn.market.cost_per_storage_mb[dsafe]
            ),
            bw_cost=st.bw_cost.at[dsafe].add(
                migrated.astype(jnp.float32)
                * vms.image_mb[v]
                * scn.market.cost_per_bw_mb[dsafe]
            ),
        )
        if scn.topology is not None:
            # open the image transfer on the link ledger
            st = st.replace(
                link_busy=st.link_busy.at[origin, dsafe].add(
                    migrated.astype(jnp.int32)),
                vm_xfer_src=st.vm_xfer_src.at[v].set(
                    jnp.where(migrated, origin, st.vm_xfer_src[v])),
                vm_xfer_dst=st.vm_xfer_dst.at[v].set(
                    jnp.where(migrated, dsafe, st.vm_xfer_dst[v])),
                vm_xfer_rem=st.vm_xfer_rem.at[v].set(
                    jnp.where(migrated, vms.image_mb[v], st.vm_xfer_rem[v])),
                vm_xfer_share=st.vm_xfer_share.at[v].set(
                    jnp.where(migrated, share0, st.vm_xfer_share[v])),
            )
        return st, found

    state, placed = jax.lax.scan(
        place_one, state, jnp.arange(vms.n_vms, dtype=jnp.int32)
    )
    return state, jnp.sum(placed.astype(jnp.int32))


def live_migrate(
    scn: Scenario, state: SimState, v: Array, dst_dc: Array, ok: Array,
    host_ok: Array | None = None,
) -> tuple[SimState, Array]:
    """Commit one runtime VM move decided by the CloudCoordinator policies
    (step.MigrationInstrument, DESIGN.md §8).

    Stop-and-copy semantics, ordered within one event: the *source* slot is
    released first (a due creation in the same step may take it), then a slot
    at ``dst_dc`` is occupied immediately (first-fit, or best-fit under
    ``Policy.best_fit``) so the arrival can never fail, and the VM becomes
    unavailable until ``t + migration_fixed_s + image/bw`` through the
    existing ``vm_avail_t`` / ``K_MIGRATION`` machinery.  In-flight cloudlets
    keep their accrued ``rem_mi`` — rates simply gate to zero while the image
    is in transit.  The image transfer is billed on the destination's
    bandwidth meter, exactly like a creation-time federation migration.

    ``v``/``dst_dc`` are traced scalars; ``ok`` gates the whole commit, so a
    disabled policy is a no-op inside the same compiled program.  ``host_ok``
    (``[D, H]`` bool) further restricts the landing slot — the evacuation
    coordinator passes its safe-host mask so a drain never lands inside the
    blast radius it is fleeing (DESIGN.md §9).  Returns ``(state', moved)``.
    """
    hosts, vms, pol = scn.hosts, scn.vms, scn.policy
    D, H = hosts.cores.shape
    V = vms.n_vms

    fits = slot_feasible(scn, state, v)[dst_dc]                   # [H]
    if host_ok is not None:
        fits = fits & host_ok[dst_dc]
    host_key = jnp.where(
        pol.best_fit,
        state.free_ram[dst_dc] - vms.ram_mb[v],
        jnp.arange(H, dtype=jnp.float32),
    )
    h = jnp.argmin(jnp.where(fits, host_key, jnp.inf))
    found = ok & jnp.any(fits)

    src_d = jnp.clip(state.vm_dc[v], 0, D - 1)
    # source releases first: the departing VM's slot is free for this step's
    # creations (and, degenerately, for its own re-placement — the policies
    # exclude dst == src, so the ordering is only ever release -> occupy)
    state = _return_resources(scn, state, (jnp.arange(V) == v) & found)

    w = found.astype(jnp.float32)
    dsafe = jnp.where(found, dst_dc, 0)
    hsafe = jnp.where(found, h, 0)
    if scn.topology is not None:
        # fair share on the (src, dst) link: full capacity when idle (bitwise
        # the old point-to-point divisor), split k+1 ways when contended
        share0 = scn.topology.bw_mbps[src_d, dsafe] / (
            state.link_busy[src_d, dsafe] + 1
        ).astype(jnp.float32)
        delay = (
            pol.migration_fixed_s
            + scn.topology.latency_s[src_d, dsafe]
            + vms.image_mb[v] / jnp.maximum(share0, 1e-6)
        )
        state = state.replace(
            link_busy=state.link_busy.at[src_d, dsafe].add(
                found.astype(jnp.int32)),
            vm_xfer_src=state.vm_xfer_src.at[v].set(
                jnp.where(found, src_d, state.vm_xfer_src[v])),
            vm_xfer_dst=state.vm_xfer_dst.at[v].set(
                jnp.where(found, dsafe, state.vm_xfer_dst[v])),
            vm_xfer_rem=state.vm_xfer_rem.at[v].set(
                jnp.where(found, vms.image_mb[v], state.vm_xfer_rem[v])),
            vm_xfer_share=state.vm_xfer_share.at[v].set(
                jnp.where(found, share0, state.vm_xfer_share[v])),
        )
    else:
        delay = pol.migration_fixed_s + vms.image_mb[v] / jnp.maximum(
            pol.interdc_bw_mbps, 1e-6
        )
    state = state.replace(
        vm_dc=state.vm_dc.at[v].set(
            jnp.where(found, dst_dc, state.vm_dc[v])),
        vm_host=state.vm_host.at[v].set(
            jnp.where(found, h, state.vm_host[v])),
        vm_avail_t=state.vm_avail_t.at[v].set(
            jnp.where(found, state.t + delay, state.vm_avail_t[v])),
        vm_migrations=state.vm_migrations.at[v].add(found.astype(jnp.int32)),
        vm_mig_src=state.vm_mig_src.at[v].set(
            jnp.where(found, src_d, state.vm_mig_src[v])),
        free_ram=state.free_ram.at[dsafe, hsafe].add(-w * vms.ram_mb[v]),
        free_storage=state.free_storage.at[dsafe, hsafe].add(
            -w * vms.storage_mb[v]),
        free_bw=state.free_bw.at[dsafe, hsafe].add(-w * vms.bw_mbps[v]),
        free_cores=state.free_cores.at[dsafe, hsafe].add(-w * vms.cores[v]),
        free_kv=state.free_kv.at[dsafe, hsafe].add(-w * vms.kv_blocks[v]),
        bw_cost=state.bw_cost.at[dsafe].add(
            w * vms.image_mb[v] * scn.market.cost_per_bw_mb[dsafe]),
    )
    return state, found


def eligible_dispatch_vms(scn: Scenario, state: SimState) -> Array:
    """[V] bool — VMs the broker may route service cloudlets to.

    Booting VMs (placed, ``vm_avail_t`` in the future) are eligible: the work
    queues on them and starts when the image is up, exactly like a fixed
    binding submitted before its VM finished creating.
    """
    return (
        scn.vms.exists
        & state.vm_placed
        & ~state.vm_failed
        & ~state.vm_released
        & (~scn.vms.pool | state.pool_active)
    )


def dispatch_cloudlets(scn: Scenario, state: SimState) -> SimState:
    """Broker dispatch: bind submitted service-routed rows (``vm == -1``).

    Each newly-due row goes to an eligible VM by least outstanding work:
    eligible VMs are ranked by assigned-but-unfinished MI per unit capacity
    and the k-th new arrival takes the k-th rank (mod the eligible count), so
    one event's batch of arrivals spreads instead of piling onto one argmin.
    If nothing is eligible the rows stay unassigned and retry at the next
    event.  Assignments are permanent — no re-balancing of queued work.

    Under ``Policy.locality_dispatch`` (topology required) the broker instead
    scores every (cloudlet, VM) pair as queue seconds + estimated stage-in
    transfer time at the link's current fair share, and takes the row argmin —
    data gravity versus queue depth (DESIGN.md §13).

    Stage-in pricing: ``input_dc == -1`` rows keep the legacy VM-local
    divisor.  ``input_dc >= 0`` rows under a topology are *not* priced here —
    their ``cl_ready_t`` stays INF and the transfer phase opens the ledger
    transfer in this same event; without a topology they bill the flat
    ``interdc_bw_mbps`` divisor when remote (VM-local bandwidth otherwise).
    """
    cls, vms, pol = scn.cloudlets, scn.vms, scn.policy
    V = vms.n_vms
    D = scn.hosts.n_dc
    due = cls.exists & (state.cl_vm < 0) & (cls.submit_t <= state.t)
    eligible = eligible_dispatch_vms(scn, state)
    n_elig = jnp.sum(eligible.astype(jnp.int32))

    outstanding = policies.vm_outstanding_mi(scn, state)
    cap = jnp.maximum(vms.cores.astype(jnp.float32) * vms.mips, 1e-9)
    queue_s = outstanding / cap
    load_key = jnp.where(eligible, queue_s, INF)
    vm_order = jnp.argsort(load_key)                     # least-loaded first

    k = jnp.cumsum(due.astype(jnp.int32)) - 1            # rank among new rows
    chosen = vm_order[jnp.where(n_elig > 0, k % jnp.maximum(n_elig, 1), 0)]

    if scn.topology is not None:
        # Data-locality-aware broker: per-(cloudlet, VM) estimated transfer
        # seconds at the link's *current* fair share (one more transfer
        # joining), added to the VM's queue depth.  Selected via jnp.where so
        # locality_dispatch=False keeps the rank dispatch bitwise.
        topo = scn.topology
        src = jnp.clip(cls.input_dc, 0, D - 1)                    # [C]
        vdc = jnp.clip(state.vm_dc, 0, D - 1)                     # [V]
        shr = topo.bw_mbps[src[:, None], vdc[None, :]] / (
            state.link_busy[src[:, None], vdc[None, :]] + 1
        ).astype(jnp.float32)                                     # [C, V]
        est = topo.latency_s[src[:, None], vdc[None, :]] + (
            cls.input_mb[:, None] / jnp.maximum(shr, 1e-6)
        )
        local = cls.input_mb[:, None] / jnp.maximum(
            vms.bw_mbps[None, :], 1e-6
        )
        est = jnp.where((cls.input_dc >= 0)[:, None], est, local)
        score = jnp.where(eligible[None, :], queue_s[None, :] + est, INF)
        chosen_loc = jnp.argmin(score, axis=1).astype(chosen.dtype)
        chosen = jnp.where(pol.locality_dispatch, chosen_loc, chosen)

    ok = due & (n_elig > 0)
    bw = jnp.maximum(vms.bw_mbps[jnp.clip(chosen, 0, V - 1)], 1e-6)
    stage_in = jnp.where(cls.input_mb > 0, cls.input_mb / bw, 0.0)
    ready = state.t + stage_in
    if scn.topology is not None:
        # network rows wait for the transfer phase to open + price the move
        ready = jnp.where(cls.input_dc >= 0, INF, ready)
    else:
        vdc_chosen = jnp.clip(
            state.vm_dc[jnp.clip(chosen, 0, V - 1)], 0, D - 1
        )
        remote = (cls.input_dc >= 0) & (cls.input_dc != vdc_chosen)
        ready = jnp.where(
            remote,
            state.t + cls.input_mb / jnp.maximum(pol.interdc_bw_mbps, 1e-6),
            ready,
        )
    return state.replace(
        cl_vm=jnp.where(ok, chosen, state.cl_vm),
        cl_ready_t=jnp.where(ok, ready, state.cl_ready_t),
    )


def _staging_due(scn: Scenario, state: SimState) -> Array:
    """[C] network stage-ins ready to open at the current clock.

    A row opens once it is submitted, bound to a placed VM, and neither
    already in flight (``cl_xfer_dst >= 0``) nor already staged
    (``cl_ready_t`` finite).  Topology-only helper.
    """
    cls = scn.cloudlets
    vmi = jnp.clip(state.cl_vm, 0, scn.vms.n_vms - 1)
    return (
        cls.exists
        & (cls.input_dc >= 0)
        & (state.cl_vm >= 0)
        & (state.cl_xfer_dst < 0)
        & (state.cl_ready_t >= INF / 2)
        & (cls.submit_t <= state.t)
        & state.vm_placed[vmi]
    )


def settle_transfers(scn: Scenario, state: SimState) -> SimState:
    """Close finished or cancelled transfers and free their link slots.

    Runs at the top of every event (step prologue, topology only), *before*
    instruments and phases: a transfer is closed when its completion time has
    arrived (``<= t``) or was reset to INF mid-flight (the VM was evicted by
    a host failure or released — the cancellation path), so the same VM can
    immediately open a fresh transfer in this event without leaking its old
    link slot.  A no-op (bitwise) when no transfer closes.
    """
    D = scn.hosts.n_dc
    t = state.t
    vm_close = (state.vm_xfer_src >= 0) & (
        (state.vm_avail_t <= t) | (state.vm_avail_t >= INF / 2)
    )
    cl_close = (state.cl_xfer_dst >= 0) & (
        (state.cl_ready_t <= t) | (state.cl_ready_t >= INF / 2)
    )
    sv = jnp.where(vm_close, jnp.clip(state.vm_xfer_src, 0, D - 1), 0)
    dv = jnp.where(vm_close, jnp.clip(state.vm_xfer_dst, 0, D - 1), 0)
    sc = jnp.where(cl_close, jnp.clip(scn.cloudlets.input_dc, 0, D - 1), 0)
    dc_ = jnp.where(cl_close, jnp.clip(state.cl_xfer_dst, 0, D - 1), 0)
    busy = state.link_busy.at[sv, dv].add(-vm_close.astype(jnp.int32))
    busy = busy.at[sc, dc_].add(-cl_close.astype(jnp.int32))
    return state.replace(
        link_busy=busy,
        vm_xfer_src=jnp.where(vm_close, -1, state.vm_xfer_src),
        vm_xfer_dst=jnp.where(vm_close, -1, state.vm_xfer_dst),
        vm_xfer_rem=jnp.where(vm_close, 0.0, state.vm_xfer_rem),
        vm_xfer_share=jnp.where(vm_close, 0.0, state.vm_xfer_share),
        cl_xfer_dst=jnp.where(cl_close, -1, state.cl_xfer_dst),
        cl_xfer_rem=jnp.where(cl_close, 0.0, state.cl_xfer_rem),
        cl_xfer_share=jnp.where(cl_close, 0.0, state.cl_xfer_share),
    )


def transfer_needed(scn: Scenario, state: SimState) -> Array:
    """Scalar bool — the transfer phase has something to do this event."""
    return (
        jnp.any(_staging_due(scn, state))
        | jnp.any(state.vm_xfer_src >= 0)
        | jnp.any(state.cl_xfer_dst >= 0)
    )


def transfer_phase(scn: Scenario, state: SimState) -> SimState:
    """Open due stage-in transfers and re-time in-flight transfers whose
    links changed occupancy (the fair-share recompute, DESIGN.md §13).

    Runs after provision/dispatch under a scalar ``lax.cond`` (topology
    only).  The ledger invariant: ``link_share`` holds the per-transfer Mbps
    granted at the last recompute, so ``fair_share(link_busy) != link_share``
    detects exactly the links whose population changed since — settles in the
    prologue, migration commits in provision, opens here.  Transfers on
    unchanged links are left untouched (bitwise), which is what keeps
    uncontended topology runs identical to the flat path.

    Re-timing is analytic, not byte-stepped: a transfer's remaining window
    ``w = done_t - t`` is a non-bandwidth head ``h`` (fixed latency not yet
    elapsed) followed by a byte tail ``rem / share``; the new completion is
    ``t + h + rem' / share_new`` with ``rem'`` the bytes left at the old
    share.  Exact — k equal transfers sharing one link finish in exactly the
    head plus k x the lone-transfer byte time.
    """
    topo = scn.topology
    cls, vms = scn.cloudlets, scn.vms
    D = scn.hosts.n_dc
    t = state.t

    # --- open due stage-ins, priced at the post-open share ---
    opening = _staging_due(scn, state)
    vmi = jnp.clip(state.cl_vm, 0, vms.n_vms - 1)
    so = jnp.where(opening, jnp.clip(cls.input_dc, 0, D - 1), 0)
    do = jnp.where(opening, jnp.clip(state.vm_dc[vmi], 0, D - 1), 0)
    busy = state.link_busy.at[so, do].add(opening.astype(jnp.int32))
    share_new = topo.fair_share(busy)                            # [D, D]

    shr_o = share_new[so, do]
    ready_o = (
        t + topo.latency_s[so, do]
        + cls.input_mb / jnp.maximum(shr_o, 1e-6)
    )
    cl_ready_t = jnp.where(opening, ready_o, state.cl_ready_t)
    cl_xfer_dst = jnp.where(opening, do, state.cl_xfer_dst)
    cl_xfer_rem = jnp.where(opening, cls.input_mb, state.cl_xfer_rem)
    cl_xfer_share = jnp.where(opening, shr_o, state.cl_xfer_share)

    changed = share_new != state.link_share                      # [D, D]

    def retime(done_t, rem, own):
        """New (done_t, rem) after a share change at the current clock."""
        own = jnp.maximum(own, 1e-6)
        w = done_t - t                   # remaining window at the old share
        tail = rem / own                 # pure byte-transfer seconds of it
        wb = jnp.minimum(w, tail)
        head = w - wb                    # latency/fixed time still ahead
        rem2 = jnp.where(wb < tail, own * wb, rem)
        return head, rem2

    # in-flight VM image transfers on changed links
    act_v = state.vm_xfer_src >= 0
    sv = jnp.clip(state.vm_xfer_src, 0, D - 1)
    dv = jnp.clip(state.vm_xfer_dst, 0, D - 1)
    hit_v = act_v & changed[sv, dv]
    snew_v = jnp.maximum(share_new[sv, dv], 1e-6)
    head_v, rem_v = retime(
        state.vm_avail_t, state.vm_xfer_rem, state.vm_xfer_share
    )
    vm_avail_t = jnp.where(
        hit_v, t + head_v + rem_v / snew_v, state.vm_avail_t
    )
    vm_xfer_rem = jnp.where(hit_v, rem_v, state.vm_xfer_rem)
    vm_xfer_share = jnp.where(hit_v, snew_v, state.vm_xfer_share)

    # in-flight stage-ins on changed links (the rows just opened above are
    # excluded — they are already priced at share_new)
    act_c = (state.cl_xfer_dst >= 0) & ~opening
    sc = jnp.clip(cls.input_dc, 0, D - 1)
    dc_ = jnp.clip(state.cl_xfer_dst, 0, D - 1)
    hit_c = act_c & changed[sc, dc_]
    snew_c = jnp.maximum(share_new[sc, dc_], 1e-6)
    head_c, rem_c = retime(
        state.cl_ready_t, state.cl_xfer_rem, state.cl_xfer_share
    )
    cl_ready_t = jnp.where(hit_c, t + head_c + rem_c / snew_c, cl_ready_t)
    cl_xfer_rem = jnp.where(hit_c, rem_c, cl_xfer_rem)
    cl_xfer_share = jnp.where(hit_c, snew_c, cl_xfer_share)

    return state.replace(
        link_busy=busy,
        link_share=share_new,
        vm_avail_t=vm_avail_t,
        vm_xfer_rem=vm_xfer_rem,
        vm_xfer_share=vm_xfer_share,
        cl_ready_t=cl_ready_t,
        cl_xfer_dst=cl_xfer_dst,
        cl_xfer_rem=cl_xfer_rem,
        cl_xfer_share=cl_xfer_share,
    )


def demand_load(scn: Scenario, state: SimState) -> Array:
    """[D] ready-but-unfinished MIPS demand / DC capacity — the autoscaler's
    pressure signal.

    Allocation-based utilization (energy.dc_utilization) cannot drive
    scale-up: space-shared grants are activity-independent, so an idle fleet
    reads as busy.  Demand counts every ready, unfinished cloudlet's desired
    consumption (cores x its VM's MIPS) whether or not the host throttles it,
    so queued work pushes the reading above 1 — run-queue pressure, exactly
    what threshold scaling should react to (DESIGN.md §7).
    """
    D = scn.hosts.n_dc
    vm_demand = policies.vm_demand_mips(scn, state)               # [V]
    dc = jnp.clip(state.vm_dc, 0, D - 1)
    demand = jnp.zeros((D,), jnp.float32).at[dc].add(vm_demand)
    return demand / jnp.maximum(dc_capacity_mips(scn), 1e-9)


def sense_load(scn: Scenario, state: SimState) -> Array:
    """[D] Sensor reading: fraction of RAM capacity currently committed."""
    total = jnp.sum(
        jnp.where(scn.hosts.exists, scn.hosts.ram_mb, 0.0), axis=1
    )
    free = jnp.sum(jnp.where(scn.hosts.exists, state.free_ram, 0.0), axis=1)
    return jnp.where(total > 0, 1.0 - free / total, 1.0)
