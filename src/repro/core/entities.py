"""CloudSim entities as struct-of-arrays pytrees.

Paper mapping (§3.1, §4):

===============  =============================================================
CloudSim class   Here
===============  =============================================================
Datacenter       the leading axis ``d`` of every ``[D, H]`` host array
Host             one column of the ``Hosts`` arrays
VirtualMachine   one row of ``VMRequests`` + per-VM state in ``SimState``
Cloudlet         one row of ``Cloudlets`` + per-cloudlet state in ``SimState``
DatacenterBroker the arrival schedule baked into ``request_t`` / ``submit_t``
SANStorage       ``input_mb``/``output_mb`` transfer latency + bandwidth cost
CloudCoordinator ``sensed_load`` + the federation placement rule (provision.py)
                 + the runtime migration policies (step.MigrationInstrument)
Sensor           the periodic ``sensed_load`` refresh (engine.py tick)
CIS registry     implicit: placement searches the global ``[D, H]`` host table
===============  =============================================================

All sizes (D datacenters, H hosts/DC, V VMs, C cloudlets) are static shapes;
all *values* — including the policy selectors — are traced, so one compiled
engine serves an entire campaign (policy x seed x workload sweep) via vmap.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import Array

from repro.core.pytree import pytree_dataclass

# Scheduling policies (paper §3.2, Figure 4). Traced int32 values.
SPACE_SHARED = 0
TIME_SHARED = 1

# A time/MI that behaves as "never/unreachable".
INF = jnp.float32(3.0e38)


@pytree_dataclass
class Hosts:
    """Physical machines, ``[D, H]`` per field (paper §3.1 ``Host``)."""

    cores: Array        # [D,H] i32  processing elements per host
    mips: Array         # [D,H] f32  MIPS per core
    ram_mb: Array       # [D,H] f32
    storage_mb: Array   # [D,H] f32
    bw_mbps: Array      # [D,H] f32
    kv_blocks: Array    # [D,H] f32  KV-cache blocks the host's accelerators
                        #            hold — the binding memory resource of LLM
                        #            serving (0: not a serving host, §14)
    exists: Array       # [D,H] bool (ragged datacenters are masked, not padded out)

    @property
    def n_dc(self) -> int:
        return self.cores.shape[0]

    @property
    def n_hosts(self) -> int:
        return self.cores.shape[1]


@pytree_dataclass
class VMRequests:
    """VM creation requests, ``[V]`` per field (paper §4 ``VirtualMachine``)."""

    dc: Array          # [V] i32  origin datacenter (the broker submits here)
    cores: Array       # [V] i32  required processing elements
    mips: Array        # [V] f32  required MIPS per core
    ram_mb: Array      # [V] f32
    storage_mb: Array  # [V] f32
    bw_mbps: Array     # [V] f32
    kv_blocks: Array   # [V] f32  KV-cache blocks the VM (a serving replica)
                       #          reserves on its host — its decode-batch pool
    request_t: Array   # [V] f32  when the broker asks for the VM
    image_mb: Array    # [V] f32  VM image size — migration transfer volume
    exists: Array      # [V] bool
    pool: Array        # [V] bool spare auto-scaling rows: held inactive until
                       #          the AutoscaleInstrument activates them

    @property
    def n_vms(self) -> int:
        return self.dc.shape[0]


@pytree_dataclass
class Cloudlets:
    """Application task units, ``[C]`` per field (paper §4 ``Cloudlet``).

    ``length_mi`` is per-core million-instructions (GridSim convention); a
    cloudlet needing ``cores`` PEs advances on each of them at its share rate.
    Rows must be ordered by ``submit_t`` (ties by row) — FCFS below is row
    order, exactly CloudSim's arrival-ordered queues.

    ``vm == -1`` marks a *service-routed* row: the broker dispatches it at
    submit time to the least-loaded active VM (including activated pool VMs),
    which is what makes horizontal auto-scaling visible to the application
    (DESIGN.md §7).  ``vm >= 0`` rows keep CloudSim's fixed binding.

    ``deadline`` is the per-cloudlet SLA: the absolute sim time by which the
    row must finish (INF = no guarantee).  A row violates its SLA when it
    finishes later — or never finishes at all (DESIGN.md §9).

    ``input_dc >= 0`` declares where the row's ``input_mb`` lives: the image
    must be staged from that datacenter to the assigned VM's DC before
    execution.  Under a ``Scenario.topology`` the stage-in becomes a real
    network transfer drawing fair-share bandwidth from the link ledger
    (DESIGN.md §13); without a topology it bills the flat
    ``Policy.interdc_bw_mbps`` divisor when remote.  ``input_dc == -1`` keeps
    the legacy VM-local stage-in (``input_mb / vm_bw``).

    ``prompt_tokens > 0`` marks a *serving* row: an LLM inference request
    generating ``max_new_tokens`` tokens (``length_mi / max_new_tokens`` MI
    each), which must hold ``ceil((prompt + generated) / block_tokens)`` KV
    blocks of its VM's pool while in the decode batch (DESIGN.md §14).
    ``prompt_tokens == 0`` rows keep classic batch-cloudlet semantics.
    """

    vm: Array         # [C] i32  target VM (-1: broker-dispatched at submit)
    length_mi: Array  # [C] f32
    cores: Array      # [C] i32
    submit_t: Array   # [C] f32
    input_mb: Array   # [C] f32  staged in before execution (SAN transfer)
    input_dc: Array   # [C] i32  datacenter holding the input data (-1: VM-local)
    output_mb: Array  # [C] f32  staged out at completion
    deadline: Array   # [C] f32  absolute SLA finish time (INF: none)
    prompt_tokens: Array   # [C] f32  prompt length; > 0 marks a serving row
    max_new_tokens: Array  # [C] f32  decode budget of a serving row (its
                           #          length_mi spreads evenly across tokens)
    exists: Array     # [C] bool

    @property
    def n_cloudlets(self) -> int:
        return self.vm.shape[0]


@pytree_dataclass
class Outages:
    """Per-host failure/repair schedule, ``[D, H, K]`` per field (K = max
    outages per host, a static shape; DESIGN.md §9).

    Times are absolute sim seconds; a host is *down* during
    ``[fail_t[k], repair_t[k])``.  Windows along K are disjoint and sorted by
    construction (``workload.host_outages``); INF entries are padding ("no
    k-th outage"), which is how an MTBF = ∞ control shares shapes — and the
    compiled program — with failing rows in one vmapped campaign.
    """

    fail_t: Array    # [D,H,K] f32 outage starts (INF: padding)
    repair_t: Array  # [D,H,K] f32 outage ends

    def down_at(self, t) -> Array:
        """[D, H] bool — host inside an outage window at time ``t``."""
        return jnp.any((self.fail_t <= t) & (t < self.repair_t), axis=-1)

    def next_fail_after(self, t) -> Array:
        """[D, H] earliest failure time strictly after ``t`` (INF: none)."""
        return jnp.min(jnp.where(self.fail_t > t, self.fail_t, INF), axis=-1)

    def next_repair_after(self, t) -> Array:
        """[D, H] earliest repair time strictly after ``t`` (INF: none)."""
        return jnp.min(
            jnp.where(self.repair_t > t, self.repair_t, INF), axis=-1
        )


@pytree_dataclass
class Market:
    """Per-datacenter prices (paper §3.3), ``[D]`` per field."""

    cost_per_cpu_sec: Array     # charged while a cloudlet executes
    cost_per_ram_mb: Array      # one-time, at VM creation (paper: "incur during
    cost_per_storage_mb: Array  # virtual machine creation")
    cost_per_bw_mb: Array       # per MB transferred (cloudlet IO + migration)


@pytree_dataclass
class Policy:
    """All policy selectors, traced so campaigns can sweep them."""

    host_policy: Array        # scalar i32: SPACE_SHARED | TIME_SHARED (VMM level)
    vm_policy: Array          # scalar i32: cloudlet scheduler inside each VM
    federation: Array         # scalar bool: CloudCoordinator migration on/off
    core_reserving: Array     # scalar bool: provisioner also reserves PEs
    best_fit: Array           # scalar bool: best-fit (by leftover RAM) vs first-fit
    sensor_interval: Array    # scalar f32: Sensor refresh period (sim seconds)
    migration_fixed_s: Array  # scalar f32: fixed VM re-creation latency
    interdc_bw_mbps: Array    # scalar f32: inter-datacenter link for migration
    horizon: Array            # scalar f32: simulation end time
    autoscale: Array          # scalar bool: AutoscaleInstrument acts on the pool
    scale_up_thresh: Array    # scalar f32: sustained DC utilization above this
                              #             activates one pool VM per DC
    scale_down_thresh: Array  # scalar f32: DC utilization below this releases
                              #             one idle pool VM per DC (0 disables)
    # --- runtime (live) migration, DESIGN.md §8 ---
    live_migration: Array            # scalar bool: MigrationInstrument acts
    migrate_balance_thresh: Array    # scalar f32: a DC whose demand exceeds
                                     #   this may shed its busiest VM to the
                                     #   least-loaded feasible peer
    migrate_consolidate_thresh: Array  # scalar f32: a DC below this drains
                                     #   its idlest VM toward the busiest
                                     #   feasible peer (0 disables)
    # --- reliability (host failures + SLA), DESIGN.md §9 ---
    ckpt_interval: Array      # scalar f32: checkpoint spacing in per-core MI —
                              #   a host failure rolls in-flight cloudlets back
                              #   to the last completed multiple (INF: restart
                              #   from zero)
    evacuation: Array         # scalar bool: ReliabilityInstrument proactively
                              #   drains doomed hosts to federation peers
    evac_lead_s: Array        # scalar f32: evacuation alarm this long before
                              #   each scheduled host failure
    # --- contention-aware network layer, DESIGN.md §13 ---
    locality_dispatch: Array  # scalar bool: broker weighs estimated stage-in
                              #   transfer time against queue depth when
                              #   choosing a VM for service-routed cloudlets
                              #   (needs Scenario.topology; False keeps the
                              #   least-loaded rank dispatch bitwise)
    # --- LLM serving (KV-bound continuous batching), DESIGN.md §14 ---
    block_tokens: Array       # scalar f32: tokens per KV-cache block — a
                              #   serving row holds ceil(ctx / block_tokens)
                              #   blocks of its VM's pool
    batch_degradation: Array  # scalar f32: per-step decode rate of a batched
                              #   request scales by 1 / (1 + alpha * (b - 1))
                              #   for a decode batch of b (0: free batching)


@pytree_dataclass(static=("max_steps", "sweep_impl"))
class Scenario:
    """A complete experiment: infrastructure + workload + policy + prices.

    ``power`` and ``topology`` (core/energy.py) are optional: the paper's
    stated future work — energy accounting and BRITE-style inter-DC links —
    activate when provided and change nothing when None.  ``outages`` (an
    ``Outages`` schedule, usually from ``workload.host_outages``) activates
    the reliability subsystem — K_FAILURE/K_REPAIR events, eviction with
    checkpoint rollback, SLA/downtime accounting (DESIGN.md §9) — and
    likewise changes nothing when None.

    ``instruments`` holds *extra* step.Instrument observables, threaded
    through the event loop after the defaults (sensor, market, energy); their
    array fields are traced data, so campaigns may vmap over them.
    """

    hosts: Hosts
    vms: VMRequests
    cloudlets: Cloudlets
    market: Market
    policy: Policy
    power: object = None        # energy.PowerModel | None
    topology: object = None     # energy.Topology | None
    outages: object = None      # Outages | None — per-host failure schedule
    instruments: tuple = ()     # tuple[step.Instrument, ...] extra observables
    max_steps: int = 0          # 0 -> derived bound (see step.default_max_steps)
    sweep_impl: str = "jnp"     # "jnp" | "pallas" — advance-sweep implementation


@pytree_dataclass
class SimState:
    """Everything the event loop carries (one pytree through while_loop)."""

    t: Array            # scalar f32 simulation clock
    step: Array         # scalar i32 event-batch counter
    # --- VM lifecycle ---
    vm_host: Array       # [V] i32 host index within vm_dc, -1 if unplaced
    vm_dc: Array         # [V] i32 current datacenter (!= origin after migration)
    vm_placed: Array     # [V] bool
    vm_failed: Array     # [V] bool (terminal: creation rejected everywhere —
                         #          never set, and never cleared, by the
                         #          transient host-failure path, DESIGN.md §9)
    vm_evicted: Array    # [V] bool transient: lost its slot to a host failure,
                         #          re-queued through the creation path; cleared
                         #          once placed and available again
    vm_avail_t: Array    # [V] f32 creation/migration completes at this time
    vm_released: Array   # [V] bool resources returned after all work done
    vm_migrations: Array # [V] i32
    vm_mig_src: Array    # [V] i32 source DC of an in-flight *live* migration
                         #         (-1 at rest / once arrived) — the fixed-shape
                         #         pending-move marker, DESIGN.md §8
    pool_active: Array   # [V] bool pool row activated by the autoscaler
                         #          (inactive -> activating -> active -> released)
    # --- host free capacity (provisioner view) ---
    host_up: Array       # [D,H] bool host currently powered/working (failure
                         #            windows flip this, DESIGN.md §9)
    free_ram: Array      # [D,H] f32
    free_storage: Array  # [D,H] f32
    free_bw: Array       # [D,H] f32
    free_cores: Array    # [D,H] f32 (only enforced when core_reserving)
    free_kv: Array       # [D,H] f32 KV-cache blocks not reserved by placed
                         #           serving VMs (DESIGN.md §14)
    # --- cloudlet execution ---
    cl_vm: Array         # [C] i32 current VM assignment; rows submitted with
                         #         vm == -1 are broker-dispatched at submit time
    cl_ready_t: Array    # [C] f32 stage-in completes (INF until dispatched)
    cl_admitted: Array   # [C] bool serving row currently in its VM's decode
                         #          batch (admission gated on free KV blocks)
    cl_kv: Array         # [C] f32 KV blocks the row holds in its VM's pool
                         #         (0 while waiting / preempted / finished)
    rem_mi: Array        # [C] f32 remaining million-instructions (per core)
    cl_rollback_mi: Array  # [C] f32 work re-done after failures: total MI added
                           #         back to rem_mi by checkpoint rollbacks
    started: Array       # [C] bool
    start_t: Array       # [C] f32 (INF until started)
    finish_t: Array      # [C] f32 (INF until finished)
    cpu_time: Array      # [C] f32 accumulated executing seconds
    # --- federation ---
    sensed_load: Array   # [D] f32 last Sensor reading per DC
    last_tick: Array     # scalar f32
    # --- market accounting (per DC) ---
    cpu_cost: Array      # [D] f32
    ram_cost: Array      # [D] f32
    storage_cost: Array  # [D] f32
    bw_cost: Array       # [D] f32
    energy_j: Array      # [D] f32 (0 unless Scenario.power is set)
    # --- reliability accounting (0 unless Scenario.outages is set) ---
    vm_downtime: Array   # [V] f32 seconds spent evicted/awaiting recovery
    n_evacuations: Array # scalar i32 proactive drains committed
    # --- contention-aware transfer ledger (idle unless Scenario.topology is
    #     set; fixed [D,D]/[V]/[C] shapes so one compiled program serves
    #     topology campaigns, DESIGN.md §13) ---
    link_busy: Array     # [D,D] i32 active transfers per directed DC link
    link_share: Array    # [D,D] f32 fair-share Mbps granted per transfer at
                         #           the last transfer-phase recompute
                         #           (bw / max(busy, 1); doubles as the
                         #           occupancy-change detector)
    vm_xfer_src: Array   # [V] i32 source DC of the VM's in-flight image
                         #         transfer (-1: no active transfer)
    vm_xfer_dst: Array   # [V] i32 destination DC of that transfer (pinned at
                         #         commit: eviction may reset vm_dc before the
                         #         ledger slot is freed)
    vm_xfer_rem: Array   # [V] f32 MB still to move as of the last recompute
    vm_xfer_share: Array # [V] f32 Mbps this transfer currently receives
    cl_xfer_dst: Array   # [C] i32 destination DC of the cloudlet's in-flight
    cl_xfer_rem: Array   # [C] f32   stage-in transfer (-1 / MB / Mbps,
    cl_xfer_share: Array # [C] f32   mirroring the VM transfer columns)


@pytree_dataclass
class SimResult:
    """Derived outcome of one simulation (what the paper's tables report)."""

    finish_t: Array      # [C]
    start_t: Array       # [C]
    cl_vm: Array         # [C] final VM binding (service rows: the broker's
                         #     dispatch choice; -1 if never dispatched)
    turnaround: Array    # [C] finish - submit (INF for never-finished)
    makespan: Array      # scalar: max finish over finished cloudlets
    mean_turnaround: Array  # scalar over finished cloudlets
    n_finished: Array    # scalar i32
    n_events: Array      # scalar i32 event batches processed
    n_migrations: Array  # scalar i32
    vm_placed: Array     # [V] bool
    vm_dc: Array         # [V] i32 final datacenter
    vm_failed: Array     # [V] bool
    cpu_cost: Array      # [D]
    ram_cost: Array      # [D]
    storage_cost: Array  # [D]
    bw_cost: Array       # [D]
    energy_j: Array      # [D]
    total_cost: Array    # scalar
    end_t: Array         # scalar: clock when the loop exited
    # --- SLA / reliability (DESIGN.md §9) ---
    sla_violations: Array  # scalar i32: existing cloudlets that finished past
                           #             their deadline, or never finished
    downtime: Array        # scalar f32: total VM-seconds lost to failures
                           #             (evicted + recovery transfer windows)
    n_evacuations: Array   # scalar i32: proactive pre-failure drains
    # --- serving tail latency (DESIGN.md §14; INF when no serving rows) ---
    ttft_p50: Array        # scalar f32: median time-to-first-token over
                           #             finished serving rows
    ttft_p99: Array        # scalar f32: p99 time-to-first-token
    tpot_p50: Array        # scalar f32: median time-per-output-token
    tpot_p99: Array        # scalar f32: p99 time-per-output-token


def finished_mask(res: SimResult) -> Array:
    return jnp.isfinite(res.finish_t) & (res.finish_t < INF / 2)
