"""Simulation campaigns: batch-major sweeps, sharded chunks, streaming folds.

What cloud researchers actually run with CloudSim is not one simulation but
*sweeps* — policy x seed x workload grids.  Because the engine is a pure
function with traced policy/workload values and static shapes, a campaign is
``simulate`` on the stacked scenario pytree — the batch-major step loop
advances every row natively, with batch-global phase skipping and early-exit
masking (DESIGN.md §10).  This module turns that kernel into a
million-scenario product (DESIGN.md §12):

* ``run_campaign(batched, chunk_size=...)`` — slice the campaign axis into
  fixed-size chunks through ONE compiled program (trailing chunk padded by
  repeating the last row, then trimmed/masked), donating each chunk's
  output-aliasable buffers so working memory is bounded by one chunk.
* ``run_campaign(..., mesh=...)`` — shard each chunk's campaign axis across
  ``mesh[axis]`` via ``shard_map`` (PartitionSpecs from
  ``dist.sharding.campaign_pspec_tree``): shards simulate their rows fully
  locally, so the collective term of this workload is exactly zero and a
  256-device mesh evaluates 256 sub-campaigns concurrently.
* ``run_campaign(..., reduce=...)`` — fold each chunk's ``SimResult`` into
  fixed-shape ``CampaignReducer`` carries *inside the compiled chunk
  program*: the ``[N, ...]`` result pytree is never materialized, so sweep
  size is bounded by wall clock, not memory (core/reducers.py).

``core/search.py`` drives these three together: successive-halving over
policy grids where every rung re-enters the same compiled chunk program.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from repro.core.engine import simulate
from repro.core.entities import Scenario, SimResult
from repro.core.reducers import CampaignReducer
from repro.dist.compat import shard_map as _shard_map


def stack_scenarios(scenarios: list[Scenario]) -> Scenario:
    """Stack same-shape scenarios along a new leading campaign axis.

    Static fields (``max_steps``, ``sweep_impl``) are jit-cache metadata, not
    arrays: they cannot vary across one campaign, so disagreement is an error
    (it used to silently keep the first scenario's values).
    """
    if not scenarios:
        raise ValueError("empty campaign")
    ref = scenarios[0]
    for i, scn in enumerate(scenarios[1:], start=1):
        for field in ("max_steps", "sweep_impl"):
            a, b = getattr(ref, field), getattr(scn, field)
            if a != b:
                raise ValueError(
                    f"stack_scenarios: scenario {i} has {field}={b!r} but "
                    f"scenario 0 has {a!r}; static fields must agree across "
                    "a campaign (split into per-value campaigns or set them "
                    "uniformly)"
                )
    ref_treedef = jax.tree.structure(ref)
    for i, scn in enumerate(scenarios[1:], start=1):
        td = jax.tree.structure(scn)
        if td != ref_treedef:
            raise ValueError(
                f"stack_scenarios: scenario {i} has pytree structure {td} "
                f"but scenario 0 has {ref_treedef}; power/topology/instrument "
                "attachments must agree across a campaign"
            )
    return jax.tree.map(lambda *xs: jnp.stack(xs), *scenarios)


def _campaign_len(batched: Scenario) -> int:
    return jax.tree.leaves(batched)[0].shape[0]


def broadcast_campaign(template: Scenario, n: int, **overrides) -> Scenario:
    """Broadcast one Scenario to an ``n``-point campaign, substituting the
    batched subtrees that actually vary.

    The grid builder for generated workloads: infrastructure/market leaves
    broadcast to a leading campaign axis; vmapped-generated ``cloudlets=``
    and swept ``policy=`` pytrees (leading axis ``n``) replace their
    broadcast counterparts.  Static fields pass through untouched, so the
    result feeds straight into ``run_campaign`` — e.g. a 64-point
    arrival-rate x scale-threshold sweep in one vmap:

        keys = jax.random.split(key, 64)
        cls = jax.vmap(lambda k, r: workload.generate_cloudlets(k, C, rate=r)
                       )(keys, rates)
        pol = jax.vmap(lambda u: template.policy.replace(scale_up_thresh=u)
                       )(threshs)
        res = run_campaign(broadcast_campaign(template, 64,
                                              cloudlets=cls, policy=pol))
    """
    batched = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n,) + jnp.shape(x)), template
    )
    for name, sub in overrides.items():
        for leaf in jax.tree.leaves(sub):
            if jnp.ndim(leaf) == 0 or jnp.shape(leaf)[0] != n:
                raise ValueError(
                    f"broadcast_campaign: override {name!r} has a leaf of "
                    f"shape {jnp.shape(leaf)}; every leaf needs leading dim "
                    f"{n} (vmap the builder over the campaign axis)"
                )
    return batched.replace(**overrides)


# `simulate` detects the stacked campaign axis by rank and runs the
# batch-major step loop (engine.is_batched): the campaign dimension lives
# inside the compiled program, not in an outer vmap, so the expensive event
# phases skip on batch-global predicates (DESIGN.md §10).
_run_whole = jax.jit(simulate)


def _sharded_simulate(chunk: Scenario, mesh, axis: str) -> SimResult:
    """``simulate`` with the chunk's campaign axis shard_mapped over
    ``mesh[axis]``.

    In-specs come from the ``dist.sharding`` campaign rule
    (``campaign_pspec_tree``): leading axis on ``mesh[axis]``, everything
    else replicated.  Each shard's sub-campaign keeps its leading rank, so
    ``engine.is_batched`` still routes it through the batch-major step —
    per-shard results are bitwise those of the unsharded run.  Replication
    checking is off (the compat shim): the while-loop carry mixes varying
    per-row state with scalars the static checker cannot prove replicated.
    """
    from repro.dist.sharding import campaign_pspec_tree

    in_tree = campaign_pspec_tree(chunk, mesh, axis)
    pspec = jax.sharding.PartitionSpec
    specs = jax.tree.leaves(in_tree, is_leaf=lambda x: isinstance(x, pspec))
    if any(s and s[0] is None for s in specs):
        n = _campaign_len(chunk)
        raise ValueError(
            f"campaign axis of {n} rows is not divisible by mesh axis "
            f"{axis!r} (size {dict(mesh.shape)[axis]}); pick a chunk_size "
            "that divides"
        )
    run = _shard_map(
        simulate, mesh=mesh, in_specs=(in_tree,), out_specs=pspec(axis)
    )
    return run(chunk)


def _sim_fn(mesh, axis: str):
    if mesh is None:
        return simulate
    return lambda chunk: _sharded_simulate(chunk, mesh, axis)


@partial(jax.jit, static_argnums=(1, 2))
def _run_whole_sharded(batched: Scenario, mesh, axis: str) -> SimResult:
    return _sharded_simulate(batched, mesh, axis)


# --------------------------------------------------------------------------
# chunked execution with *effective* buffer donation
#
# Donating the whole Scenario pytree is a no-op that warns on every chunk
# ("Some donated buffers were not usable"): XLA can only reuse a donated
# input buffer for an output of identical shape/dtype, and most Scenario
# leaves have no SimResult counterpart.  So the chunk runner donates exactly
# the subset of leaves that CAN alias an output (matched by (shape, dtype)
# multiset against eval_shape of the result) and passes the rest undonated.
# tests/test_campaign.py promotes the donation UserWarning to an error, so a
# regression to wholesale donation fails loudly.
#
# The streaming runner (_run_chunk_fold) donates the reducer *carries*
# instead: its only outputs are the carries, which alias their input buffers
# exactly, while the scenario chunk has no output counterpart at all.
# --------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _donate_mask(treedef, avals: tuple) -> tuple[bool, ...]:
    """Per-leaf: may this input buffer alias some output buffer?"""
    chunk = jax.tree.unflatten(
        treedef, [jax.ShapeDtypeStruct(s, d) for s, d in avals]
    )
    out = jax.eval_shape(simulate, chunk)
    budget: dict = {}
    for leaf in jax.tree.leaves(out):
        key = (leaf.shape, leaf.dtype)
        budget[key] = budget.get(key, 0) + 1
    mask = []
    for s, d in avals:
        n = budget.get((s, d), 0)
        mask.append(n > 0)
        if n:
            budget[(s, d)] = n - 1
    return tuple(mask)


@partial(jax.jit, static_argnums=(2, 3, 4, 5), donate_argnums=(0,))
def _run_chunk_split(donated, kept, mask, treedef, mesh=None, axis="data"):
    it_d, it_k = iter(donated), iter(kept)
    leaves = [next(it_d) if m else next(it_k) for m in mask]
    return _sim_fn(mesh, axis)(jax.tree.unflatten(treedef, leaves))


def _split_chunk(chunk: Scenario):
    """(donated leaves, kept leaves, mask, treedef) for the chunk runner."""
    leaves, treedef = jax.tree.flatten(chunk)
    avals = tuple((l.shape, l.dtype) for l in leaves)
    mask = _donate_mask(treedef, avals)
    donated = tuple(l for l, m in zip(leaves, mask) if m)
    kept = tuple(l for l, m in zip(leaves, mask) if not m)
    return donated, kept, mask, treedef


def _run_chunk(chunk: Scenario, mesh=None, axis: str = "data") -> SimResult:
    donated, kept, mask, treedef = _split_chunk(chunk)
    return _run_chunk_split(donated, kept, mask, treedef, mesh, axis)


def lower_chunk(chunk: Scenario, mesh=None, axis: str = "data") -> tuple[str, int]:
    """AOT-compile one campaign chunk through the donating runner and return
    ``(optimized_hlo_text, n_donated)``.

    The HLO module header carries XLA's ``input_output_alias`` table; simlint
    rule R2 checks it covers every ``_donate_mask``-donatable leaf, catching
    the PR-2 "donation that never aliased" regression class statically —
    without running a campaign.  With ``mesh`` the chunk is lowered through
    the shard_map runner instead (the ``campaign_sharded`` lint entry).
    """
    donated, kept, mask, treedef = _split_chunk(chunk)
    compiled = _run_chunk_split.lower(
        donated, kept, mask, treedef, mesh, axis
    ).compile()
    return compiled.as_text(), sum(mask)


# --------------------------------------------------------------------------
# streaming reductions: fold chunk results into fixed-shape carries
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnums=(3, 4, 5, 6), donate_argnums=(2,))
def _run_chunk_fold(leaves, bounds, carries, treedef, reducers, mesh, axis):
    scn = jax.tree.unflatten(treedef, leaves)
    res = _sim_fn(mesh, axis)(scn)
    size = jax.tree.leaves(scn)[0].shape[0]
    index = bounds[0] + jnp.arange(size, dtype=jnp.int32)
    valid = index < bounds[1]
    return tuple(
        r.fold(c, scn, res, index, valid)
        for r, c in zip(reducers, carries)
    )


def _normalize_reduce(reduce):
    """-> (keys | None, tuple_of_reducers, single_flag)."""
    if isinstance(reduce, CampaignReducer):
        return None, (reduce,), True
    if isinstance(reduce, dict):
        for k, r in reduce.items():
            if not isinstance(r, CampaignReducer):
                raise TypeError(f"reduce[{k!r}] is not a CampaignReducer")
        return tuple(reduce), tuple(reduce.values()), False
    raise TypeError(
        f"reduce must be a CampaignReducer or a dict of them, got {reduce!r}"
    )


def _run_reduced(batched: Scenario, chunk_size: int | None, reduce,
                 mesh, axis: str):
    keys, reducers, single = _normalize_reduce(reduce)
    n = _campaign_len(batched)
    chunk = chunk_size or n

    leaves0, treedef = jax.tree.flatten(batched)
    chunk_avals = jax.tree.unflatten(treedef, [
        jax.ShapeDtypeStruct((chunk,) + l.shape[1:], l.dtype)
        for l in leaves0
    ])
    res_avals = jax.eval_shape(simulate, chunk_avals)
    carries = tuple(r.init(chunk_avals, res_avals) for r in reducers)

    # With a mesh, pin every input's sharding before each fold call:
    # otherwise arrays that flow back from a previous fold (search-driver
    # survivors, the carries themselves) arrive committed to mesh shardings
    # while fresh chunks arrive uncommitted, and the differing shardings
    # fork the jit cache per call — the exact hazard simlint R5 probes.
    leaf_shardings = rep = None
    if mesh is not None:
        from repro.dist.sharding import campaign_pspec_tree, named

        leaf_shardings = jax.tree.leaves(
            named(mesh, campaign_pspec_tree(chunk_avals, mesh, axis)),
            is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding),
        )
        rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    for lo in range(0, n, chunk):
        def _slice(x):
            c = x[lo:lo + chunk]
            short = chunk - c.shape[0]
            if short:
                pad = jnp.broadcast_to(x[-1:], (short,) + x.shape[1:])
                c = jnp.concatenate([c, pad])
            return c

        leaves = tuple(jax.tree.leaves(jax.tree.map(_slice, batched)))
        if mesh is not None:
            leaves = tuple(
                jax.device_put(l, s) for l, s in zip(leaves, leaf_shardings)
            )
            carries = jax.device_put(carries, rep)
        # (lo, n) ride as one traced i32[2] so every chunk — first, middle,
        # padded tail — reuses the same compiled fold program
        bounds = jnp.asarray([lo, n], jnp.int32)
        carries = _run_chunk_fold(
            leaves, bounds, carries, treedef, reducers, mesh, axis
        )
    outs = tuple(r.finalize(c) for r, c in zip(reducers, carries))
    if keys is not None:
        return dict(zip(keys, outs))
    return outs[0] if single else outs


def run_campaign(
    batched: Scenario,
    chunk_size: int | None = None,
    donate: bool = False,
    reduce=None,
    mesh=None,
    axis: str = "data",
) -> SimResult:
    """Run a stacked campaign; the front door for every sweep size.

    ``chunk_size`` bounds working memory: the campaign axis is processed in
    fixed-size chunks through one compiled program (the trailing chunk is
    padded by repeating the last scenario, then trimmed), each chunk's
    output-aliasable input buffers donated to XLA.  ``donate=True`` applies
    the same donation to the unchunked local path — only safe when the
    caller is done with ``batched``.

    ``mesh`` shards every chunk's campaign axis over ``mesh[axis]`` via
    ``shard_map`` (specs from ``dist.sharding.campaign_pspec_tree``); the
    chunk size (or the whole campaign when unchunked) must be divisible by
    that mesh axis.  Shards never communicate — simulations are
    embarrassingly parallel — so this scales linearly until chunks starve.

    ``reduce`` (a ``CampaignReducer`` or dict of them, core/reducers.py)
    switches to streaming mode: each chunk's results fold into fixed-shape
    carries inside the compiled chunk program and only the finalized
    summary (dict mirroring ``reduce``) returns — the ``[N, ...]`` result
    pytree is never materialized, which is what makes 1e5–1e6-point sweeps
    memory-feasible (DESIGN.md §12).
    """
    if chunk_size is not None and chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    n = _campaign_len(batched)
    if mesh is not None:
        if axis not in dict(mesh.shape):
            raise ValueError(
                f"mesh has no axis {axis!r}; axes: {tuple(mesh.axis_names)}"
            )
        per = chunk_size or n
        if per % dict(mesh.shape)[axis]:
            raise ValueError(
                f"chunk of {per} rows is not divisible by mesh axis "
                f"{axis!r} (size {dict(mesh.shape)[axis]})"
            )
    if reduce is not None:
        return _run_reduced(batched, chunk_size, reduce, mesh, axis)
    if chunk_size is None:
        if mesh is None:
            return (_run_chunk if donate else _run_whole)(batched)
        from repro.dist.sharding import campaign_pspec_tree, named

        sharding = named(mesh, campaign_pspec_tree(batched, mesh, axis))
        batched = jax.device_put(batched, sharding)
        return _run_whole_sharded(batched, mesh, axis)
    results = []
    for lo in range(0, n, chunk_size):
        def _slice(x):
            c = x[lo:lo + chunk_size]
            short = chunk_size - c.shape[0]
            if short:
                pad = jnp.broadcast_to(x[-1:], (short,) + x.shape[1:])
                c = jnp.concatenate([c, pad])
            return c

        # the chunk is a fresh temporary -> donating it is always safe
        results.append(_run_chunk(jax.tree.map(_slice, batched), mesh, axis))
    return jax.tree.map(lambda *xs: jnp.concatenate(xs)[:n], *results)


def run_campaign_sharded(batched: Scenario, mesh, axis: str = "data") -> SimResult:
    """Shard the campaign's leading axis across ``mesh[axis]``.

    Kept as the one-argument spelling of ``run_campaign(batched,
    mesh=mesh)``; see there.  Each device runs its slice of scenarios
    entirely locally; there is no cross-device communication inside a
    simulation, so the collective term of this workload's roofline is
    exactly zero.
    """
    return run_campaign(batched, mesh=mesh, axis=axis)
