"""Simulation campaigns: vmap x shard_map over whole simulations.

What cloud researchers actually run with CloudSim is not one simulation but
*sweeps* — policy x seed x workload grids.  Because the engine is a pure
function with traced policy/workload values and static shapes, a campaign is
``vmap(simulate)``; on a mesh it becomes ``shard_map`` over the data axis so a
256-chip pod evaluates 256+ federated-cloud scenarios concurrently.  This is
the paper's "repeatable, controllable, free-of-cost" experimentation scaled
three orders of magnitude (DESIGN.md §2).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.engine import simulate
from repro.core.entities import Scenario, SimResult


def stack_scenarios(scenarios: list[Scenario]) -> Scenario:
    """Stack same-shape scenarios along a new leading campaign axis."""
    if not scenarios:
        raise ValueError("empty campaign")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *scenarios)


@jax.jit
def run_campaign(batched: Scenario) -> SimResult:
    """Run a stacked campaign on the local device."""
    return jax.vmap(simulate)(batched)


def run_campaign_sharded(batched: Scenario, mesh, axis: str = "data") -> SimResult:
    """Shard the campaign's leading axis across ``mesh[axis]``.

    Each device runs its slice of scenarios entirely locally; there is no
    cross-device communication inside a simulation (simulations are
    embarrassingly parallel), so the collective term of this workload's
    roofline is exactly zero — see EXPERIMENTS.md §Roofline (campaign row).
    """
    pspec = jax.sharding.PartitionSpec(axis)
    sharding = jax.sharding.NamedSharding(mesh, pspec)

    @partial(
        jax.shard_map,
        mesh=mesh,
        in_specs=(pspec,),
        out_specs=pspec,
        # while-loop carries mix varying (per-sim state) and unvarying
        # (scalars broadcast inside the loop) types; correctness is per-shard
        # independence, which vmap(simulate) guarantees
        check_vma=False,
    )
    def _run(shard: Scenario) -> SimResult:
        return jax.vmap(simulate)(shard)

    batched = jax.device_put(batched, sharding)
    return jax.jit(_run)(batched)
