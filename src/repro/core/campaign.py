"""Simulation campaigns: vmap x shard_map over whole simulations.

What cloud researchers actually run with CloudSim is not one simulation but
*sweeps* — policy x seed x workload grids.  Because the engine is a pure
function with traced policy/workload values and static shapes, a campaign is
``simulate`` on the stacked scenario pytree — the batch-major step loop
advances every row natively, with batch-global phase skipping and early-exit
masking (DESIGN.md §10); on a mesh it becomes ``shard_map`` over the data axis so a
256-chip pod evaluates 256+ federated-cloud scenarios concurrently.  This is
the paper's "repeatable, controllable, free-of-cost" experimentation scaled
three orders of magnitude (DESIGN.md §2, §5).

Memory: a vmapped while_loop materializes every scenario's full working set
at once, so 10k+-scenario sweeps can exceed device memory even though each
simulation is tiny.  ``run_campaign(batched, chunk_size=...)`` slices the
campaign axis into fixed-size chunks (one compilation, reused), donating each
chunk's buffers to XLA so working memory is bounded by one chunk.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from repro.core.engine import simulate
from repro.core.entities import Scenario, SimResult
from repro.dist.compat import shard_map as _shard_map


def stack_scenarios(scenarios: list[Scenario]) -> Scenario:
    """Stack same-shape scenarios along a new leading campaign axis.

    Static fields (``max_steps``, ``sweep_impl``) are jit-cache metadata, not
    arrays: they cannot vary across one campaign, so disagreement is an error
    (it used to silently keep the first scenario's values).
    """
    if not scenarios:
        raise ValueError("empty campaign")
    ref = scenarios[0]
    for i, scn in enumerate(scenarios[1:], start=1):
        for field in ("max_steps", "sweep_impl"):
            a, b = getattr(ref, field), getattr(scn, field)
            if a != b:
                raise ValueError(
                    f"stack_scenarios: scenario {i} has {field}={b!r} but "
                    f"scenario 0 has {a!r}; static fields must agree across "
                    "a campaign (split into per-value campaigns or set them "
                    "uniformly)"
                )
    ref_treedef = jax.tree.structure(ref)
    for i, scn in enumerate(scenarios[1:], start=1):
        td = jax.tree.structure(scn)
        if td != ref_treedef:
            raise ValueError(
                f"stack_scenarios: scenario {i} has pytree structure {td} "
                f"but scenario 0 has {ref_treedef}; power/topology/instrument "
                "attachments must agree across a campaign"
            )
    return jax.tree.map(lambda *xs: jnp.stack(xs), *scenarios)


def _campaign_len(batched: Scenario) -> int:
    return jax.tree.leaves(batched)[0].shape[0]


def broadcast_campaign(template: Scenario, n: int, **overrides) -> Scenario:
    """Broadcast one Scenario to an ``n``-point campaign, substituting the
    batched subtrees that actually vary.

    The grid builder for generated workloads: infrastructure/market leaves
    broadcast to a leading campaign axis; vmapped-generated ``cloudlets=``
    and swept ``policy=`` pytrees (leading axis ``n``) replace their
    broadcast counterparts.  Static fields pass through untouched, so the
    result feeds straight into ``run_campaign`` — e.g. a 64-point
    arrival-rate x scale-threshold sweep in one vmap:

        keys = jax.random.split(key, 64)
        cls = jax.vmap(lambda k, r: workload.generate_cloudlets(k, C, rate=r)
                       )(keys, rates)
        pol = jax.vmap(lambda u: template.policy.replace(scale_up_thresh=u)
                       )(threshs)
        res = run_campaign(broadcast_campaign(template, 64,
                                              cloudlets=cls, policy=pol))
    """
    batched = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n,) + jnp.shape(x)), template
    )
    for name, sub in overrides.items():
        for leaf in jax.tree.leaves(sub):
            if jnp.ndim(leaf) == 0 or jnp.shape(leaf)[0] != n:
                raise ValueError(
                    f"broadcast_campaign: override {name!r} has a leaf of "
                    f"shape {jnp.shape(leaf)}; every leaf needs leading dim "
                    f"{n} (vmap the builder over the campaign axis)"
                )
    return batched.replace(**overrides)


# `simulate` detects the stacked campaign axis by rank and runs the
# batch-major step loop (engine.is_batched): the campaign dimension lives
# inside the compiled program, not in an outer vmap, so the expensive event
# phases skip on batch-global predicates (DESIGN.md §10).
_run_whole = jax.jit(simulate)


# --------------------------------------------------------------------------
# chunked execution with *effective* buffer donation
#
# Donating the whole Scenario pytree is a no-op that warns on every chunk
# ("Some donated buffers were not usable"): XLA can only reuse a donated
# input buffer for an output of identical shape/dtype, and most Scenario
# leaves have no SimResult counterpart.  So the chunk runner donates exactly
# the subset of leaves that CAN alias an output (matched by (shape, dtype)
# multiset against eval_shape of the result) and passes the rest undonated.
# tests/test_campaign.py promotes the donation UserWarning to an error, so a
# regression to wholesale donation fails loudly.
# --------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _donate_mask(treedef, avals: tuple) -> tuple[bool, ...]:
    """Per-leaf: may this input buffer alias some output buffer?"""
    chunk = jax.tree.unflatten(
        treedef, [jax.ShapeDtypeStruct(s, d) for s, d in avals]
    )
    out = jax.eval_shape(simulate, chunk)
    budget: dict = {}
    for leaf in jax.tree.leaves(out):
        key = (leaf.shape, leaf.dtype)
        budget[key] = budget.get(key, 0) + 1
    mask = []
    for s, d in avals:
        n = budget.get((s, d), 0)
        mask.append(n > 0)
        if n:
            budget[(s, d)] = n - 1
    return tuple(mask)


@partial(jax.jit, static_argnums=(2, 3), donate_argnums=(0,))
def _run_chunk_split(donated, kept, mask, treedef):
    it_d, it_k = iter(donated), iter(kept)
    leaves = [next(it_d) if m else next(it_k) for m in mask]
    return simulate(jax.tree.unflatten(treedef, leaves))


def _split_chunk(chunk: Scenario):
    """(donated leaves, kept leaves, mask, treedef) for the chunk runner."""
    leaves, treedef = jax.tree.flatten(chunk)
    avals = tuple((l.shape, l.dtype) for l in leaves)
    mask = _donate_mask(treedef, avals)
    donated = tuple(l for l, m in zip(leaves, mask) if m)
    kept = tuple(l for l, m in zip(leaves, mask) if not m)
    return donated, kept, mask, treedef


def _run_chunk(chunk: Scenario) -> SimResult:
    donated, kept, mask, treedef = _split_chunk(chunk)
    return _run_chunk_split(donated, kept, mask, treedef)


def lower_chunk(chunk: Scenario) -> tuple[str, int]:
    """AOT-compile one campaign chunk through the donating runner and return
    ``(optimized_hlo_text, n_donated)``.

    The HLO module header carries XLA's ``input_output_alias`` table; simlint
    rule R2 checks it covers every ``_donate_mask``-donatable leaf, catching
    the PR-2 "donation that never aliased" regression class statically —
    without running a campaign.
    """
    donated, kept, mask, treedef = _split_chunk(chunk)
    compiled = _run_chunk_split.lower(donated, kept, mask, treedef).compile()
    return compiled.as_text(), sum(mask)


def run_campaign(
    batched: Scenario, chunk_size: int | None = None, donate: bool = False
) -> SimResult:
    """Run a stacked campaign on the local device.

    ``chunk_size`` bounds working memory: the campaign axis is processed in
    fixed-size chunks through one compiled program (the trailing chunk is
    padded by repeating the last scenario, then trimmed), each chunk's
    output-aliasable input buffers donated to XLA.  ``donate=True`` applies
    the same donation to the unchunked path — only safe when the caller is
    done with ``batched``.
    """
    if chunk_size is None:
        return (_run_chunk if donate else _run_whole)(batched)
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    n = _campaign_len(batched)
    results = []
    for lo in range(0, n, chunk_size):
        def _slice(x):
            c = x[lo:lo + chunk_size]
            short = chunk_size - c.shape[0]
            if short:
                pad = jnp.broadcast_to(x[-1:], (short,) + x.shape[1:])
                c = jnp.concatenate([c, pad])
            return c

        # the chunk is a fresh temporary -> donating it is always safe
        results.append(_run_chunk(jax.tree.map(_slice, batched)))
    return jax.tree.map(lambda *xs: jnp.concatenate(xs)[:n], *results)


def run_campaign_sharded(batched: Scenario, mesh, axis: str = "data") -> SimResult:
    """Shard the campaign's leading axis across ``mesh[axis]``.

    Each device runs its slice of scenarios entirely locally; there is no
    cross-device communication inside a simulation (simulations are
    embarrassingly parallel), so the collective term of this workload's
    roofline is exactly zero — see EXPERIMENTS.md §Roofline (campaign row).
    """
    pspec = jax.sharding.PartitionSpec(axis)
    sharding = jax.sharding.NamedSharding(mesh, pspec)

    # while-loop carries mix varying (per-sim state) and unvarying (scalars
    # broadcast inside the loop) types, so replication checking is off (the
    # compat shim); correctness is per-shard independence, which
    # the batch-major simulate guarantees
    @partial(
        _shard_map,
        mesh=mesh,
        in_specs=(pspec,),
        out_specs=pspec,
    )
    def _run(shard: Scenario) -> SimResult:
        return simulate(shard)

    batched = jax.device_put(batched, sharding)
    return jax.jit(_run)(batched)
