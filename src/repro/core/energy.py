"""Energy and network-topology models — the paper's stated future work,
implemented (§6: "power consumption, heat dissipation", "BRITE topology").

Power model (linear-in-utilization, the standard DVFS-era datacenter model):
    P(host) = P_idle + (P_peak - P_idle) * utilization
integrated over the piecewise-constant event intervals the engine already
produces, so per-DC energy falls out of the same sweep that advances work.

Topology model: an inter-DC latency/bandwidth matrix (BRITE-style edge
parameters without the generator) replacing the paper's single scalar
inter-DC link; migration delay and federated placement cost become
pair-dependent, enabling locality-aware coordinator policies.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.core import policies
from repro.core.entities import Scenario, SimState
from repro.core.pytree import pytree_dataclass


@pytree_dataclass
class PowerModel:
    """Per-DC host power parameters, [D] each.

    ``gate_idle`` models per-host power gating: a host with *no* VM holding
    resources on it draws zero instead of ``watts_idle`` — the accounting
    that makes energy-consolidation migration (DESIGN.md §8) visible.  None
    (or all-False) keeps the classic always-on datacenter model.
    """
    watts_idle: Array    # drawn whenever a host is powered
    watts_peak: Array    # at 100% core-MIPS utilization
    gate_idle: Array | None = None   # [D] bool: unoccupied hosts draw 0

    @staticmethod
    def uniform(n_dc: int, idle: float = 93.0, peak: float = 135.0,
                gate_idle: bool = False):
        # defaults: SPECpower-ish numbers for a 2009-era 1U server
        return PowerModel(
            watts_idle=jnp.full((n_dc,), idle, jnp.float32),
            watts_peak=jnp.full((n_dc,), peak, jnp.float32),
            gate_idle=jnp.full((n_dc,), gate_idle, bool),
        )


@pytree_dataclass
class Topology:
    """Inter-DC link parameters, [D, D] each (diagonal = intra-DC).

    The single bandwidth surface for every inter-DC byte: migration images,
    evacuations, and cloudlet data staging all draw from these links through
    the ``SimState.link_busy`` / ``link_share`` ledger (DESIGN.md §13).
    """
    latency_s: Array
    bw_mbps: Array

    def fair_share(self, busy: Array) -> Array:
        """[D, D] Mbps each active transfer receives under fair sharing.

        ``busy`` is the per-link active-transfer count; an idle link grants
        its full capacity (``bw / max(busy, 1)``), so a lone transfer is
        bitwise-identical to the uncontended point-to-point divisor.
        """
        return self.bw_mbps / jnp.maximum(busy, 1).astype(jnp.float32)

    @staticmethod
    def uniform(n_dc: int, latency_s: float = 0.05, bw_mbps: float = 100.0):
        lat = jnp.full((n_dc, n_dc), latency_s, jnp.float32)
        lat = lat * (1 - jnp.eye(n_dc))
        bw = jnp.full((n_dc, n_dc), bw_mbps, jnp.float32)
        return Topology(latency_s=lat, bw_mbps=bw)

    @staticmethod
    def from_coordinates(coords_km: np.ndarray, bw_mbps: float = 100.0):
        """BRITE-flavoured: latency ~ great-circle distance / 0.6c."""
        d = np.linalg.norm(
            coords_km[:, None, :] - coords_km[None, :, :], axis=-1
        )
        lat = (d * 1e3 / (0.6 * 3e8)).astype(np.float32)
        n = coords_km.shape[0]
        return Topology(
            latency_s=jnp.asarray(lat),
            bw_mbps=jnp.full((n, n), bw_mbps, jnp.float32),
        )


def host_granted_mips(
    scn: Scenario, state: SimState, vm_mips: Array | None = None
) -> Array:
    """[D, H] MIPS currently granted to VMs on each host.

    ``vm_mips`` may be supplied by a caller that already ran the policy sweep
    (the engine's EnergyInstrument passes ``StepEvent.vm_mips``) so the grant
    is integrated over exactly the interval the sweep produced.
    """
    if vm_mips is None:
        vm_mips = policies.host_level_mips(scn, state)        # [V]
    D, H = scn.hosts.cores.shape
    seg = jnp.where(
        state.vm_placed & scn.vms.exists,
        state.vm_dc * H + state.vm_host,
        D * H,
    )
    return jnp.zeros((D * H + 1,), jnp.float32).at[
        jnp.clip(seg, 0, D * H)
    ].add(vm_mips)[:-1].reshape(D, H)


def host_utilization(
    scn: Scenario, state: SimState, vm_mips: Array | None = None
) -> Array:
    """[D, H] granted / capacity, clipped to [0, 1]; 0 for capacity-less hosts."""
    granted = host_granted_mips(scn, state, vm_mips)
    cap = scn.hosts.cores.astype(jnp.float32) * scn.hosts.mips
    return jnp.where(
        cap > 0, jnp.clip(granted / jnp.maximum(cap, 1e-9), 0, 1), 0.0
    )


def dc_utilization(
    scn: Scenario, state: SimState, vm_mips: Array | None = None
) -> Array:
    """[D] capacity-weighted datacenter utilization (the Sensor's CPU view)."""
    granted = jnp.where(
        scn.hosts.exists, host_granted_mips(scn, state, vm_mips), 0.0
    )
    cap = jnp.where(
        scn.hosts.exists,
        scn.hosts.cores.astype(jnp.float32) * scn.hosts.mips,
        0.0,
    )
    total_cap = jnp.sum(cap, axis=1)
    return jnp.where(
        total_cap > 0,
        jnp.clip(jnp.sum(granted, axis=1) / jnp.maximum(total_cap, 1e-9), 0, 1),
        0.0,
    )


def host_occupied(scn: Scenario, state: SimState) -> Array:
    """[D, H] bool — at least one VM currently holds resources on the host.

    A live-migrating VM occupies its *destination* slot from departure
    (provision.live_migrate reserves it), matching the free-capacity ledger.
    """
    D, H = scn.hosts.cores.shape
    occ = state.vm_placed & ~state.vm_released & scn.vms.exists
    seg = jnp.where(occ, state.vm_dc * H + state.vm_host, D * H)
    counts = jnp.zeros((D * H + 1,), jnp.int32).at[
        jnp.clip(seg, 0, D * H)
    ].add(occ.astype(jnp.int32))
    return counts[:-1].reshape(D, H) > 0


def power_draw(
    scn: Scenario, state: SimState, vm_mips: Array | None = None
) -> Array:
    """[D] instantaneous watts given the current allocation.

    Utilization per host = granted MIPS / capacity; idle power charged for
    every existing host — the paper's always-on datacenter framing — except
    hosts that are unoccupied under a ``gate_idle`` power model, which draw
    zero (the consolidation-migration payoff, DESIGN.md §8).
    """
    util = host_utilization(scn, state, vm_mips)
    pm: PowerModel = scn.power            # type: ignore[attr-defined]
    idle = jnp.broadcast_to(
        pm.watts_idle[:, None], scn.hosts.cores.shape
    )
    if getattr(pm, "gate_idle", None) is not None:
        idle = jnp.where(
            pm.gate_idle[:, None] & ~host_occupied(scn, state), 0.0, idle
        )
    # a failed host draws nothing — it is off, not idling (DESIGN.md §9)
    watts = jnp.where(
        scn.hosts.exists & state.host_up,
        idle + (pm.watts_peak - pm.watts_idle)[:, None] * util,
        0.0,
    )
    return jnp.sum(watts, axis=1)


def migration_delay_matrix(
    scn: Scenario, image_mb: Array, policy=None
) -> Array:
    """[D, D] seconds to move a VM image between DC pairs under the topology.

    Includes ``Policy.migration_fixed_s`` (the VM re-creation latency), so the
    matrix agrees exactly with the uncontended delay the engine charges when a
    migration commits (provision.py) — analysis and placement consumers used
    to underestimate every move by the fixed term.  ``policy`` defaults to
    ``scn.policy``; pass one explicitly to price moves under a different knob
    setting without rebuilding the scenario.
    """
    topo: Topology = scn.topology         # type: ignore[attr-defined]
    pol = scn.policy if policy is None else policy
    return (
        pol.migration_fixed_s
        + topo.latency_s
        + image_mb / jnp.maximum(topo.bw_mbps, 1e-6)
    )
