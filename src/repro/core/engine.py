"""The event-driven simulation engine (paper §4.1 re-derived as dataflow).

CloudSim advances the world between *events*: rates are piecewise-constant,
so each ``updateVMsProcessing()`` sweep returns the next expected completion
time and the clock jumps straight to the earliest one.  Here the sweep is one
vectorized pass and the event loop is a ``jax.lax.while_loop``:

    next event = min( earliest cloudlet completion   (rem / rate),
                      next cloudlet ready time        (submit + stage-in),
                      next VM request,
                      next migration completion,
                      next Sensor tick,
                      horizon )

Equivalence argument (DESIGN.md §2): for CloudSim's model class — linear
work depletion under piecewise-constant allocations, with all state changes
triggered by the event kinds above — jumping to the min of those bounds and
re-running the two-level policy sweep produces the same trajectory as
SimJava's event queue, without materializing a queue at all.

The whole loop is jittable, differentiable in the rates (not used), and
vmappable: a *campaign* of thousands of simulations runs as one program
(see campaign.py), which is this paper's "repeatable, free-of-cost
experimentation" scaled to a pod.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

from repro.core import policies, provision
from repro.core.entities import (
    INF,
    Scenario,
    SimResult,
    SimState,
)


def default_max_steps(scn: Scenario) -> int:
    """Safety bound on event batches: starts + finishes + VM lifecycle + slack.

    Federation scenarios add ~horizon/sensor_interval tick events; builders
    for those pass ``Scenario.max_steps`` explicitly.
    """
    return 4 * (scn.cloudlets.n_cloudlets + scn.vms.n_vms) + 260


def init_state(scn: Scenario) -> SimState:
    hosts, vms, cls = scn.hosts, scn.vms, scn.cloudlets
    D, H = hosts.cores.shape
    V, C = vms.n_vms, cls.n_cloudlets
    f32, i32 = jnp.float32, jnp.int32
    zero_dh = jnp.zeros((D, H), f32)
    return SimState(
        t=jnp.asarray(0.0, f32),
        step=jnp.asarray(0, i32),
        vm_host=jnp.full((V,), -1, i32),
        vm_dc=vms.dc.astype(i32),
        vm_placed=jnp.zeros((V,), bool),
        vm_failed=jnp.zeros((V,), bool),
        vm_avail_t=jnp.full((V,), INF, f32),
        vm_released=jnp.zeros((V,), bool),
        vm_migrations=jnp.zeros((V,), i32),
        free_ram=jnp.where(hosts.exists, hosts.ram_mb, 0.0),
        free_storage=jnp.where(hosts.exists, hosts.storage_mb, 0.0),
        free_bw=jnp.where(hosts.exists, hosts.bw_mbps, 0.0),
        free_cores=jnp.where(hosts.exists, hosts.cores.astype(f32), 0.0),
        rem_mi=jnp.where(cls.exists, cls.length_mi, 0.0),
        started=jnp.zeros((C,), bool),
        start_t=jnp.full((C,), INF, f32),
        finish_t=jnp.where(cls.exists, INF, -INF),  # ghosts count as finished
        cpu_time=jnp.zeros((C,), f32),
        sensed_load=jnp.zeros((D,), f32),
        last_tick=jnp.asarray(0.0, f32),
        cpu_cost=jnp.zeros((D,), f32),
        ram_cost=jnp.zeros((D,), f32),
        storage_cost=jnp.zeros((D,), f32),
        bw_cost=jnp.zeros((D,), f32),
        energy_j=jnp.zeros((D,), f32),
    )


def _eps_mi(length_mi: Array) -> Array:
    """Finish tolerance: float32 work counters drift ~ulp per event (DESIGN §2,
    "f64-free"); tests bound the induced completion-time error."""
    return 1e-5 * length_mi + 0.25


def _advance_jnp(rem: Array, rate: Array, active: Array, bound_dt: Array):
    """Reference advance sweep: min-time-to-completion + work depletion.

    The Pallas twin lives in kernels/vm_update.py; ops.advance_sweep routes.
    """
    dt_fin = jnp.where(active & (rate > 0), rem / jnp.maximum(rate, 1e-30), INF)
    dt = jnp.minimum(jnp.min(dt_fin, initial=INF), bound_dt)
    new_rem = jnp.where(active, jnp.maximum(rem - rate * dt, 0.0), rem)
    return dt, new_rem


def _min_where(x: Array, mask: Array) -> Array:
    return jnp.min(jnp.where(mask, x, INF), initial=INF)


def _done_or_doomed(scn: Scenario, st: SimState) -> Array:
    fin = policies.cloudlet_finished(st)
    doomed = st.vm_failed[scn.cloudlets.vm]
    return fin | doomed | ~scn.cloudlets.exists


def simulate(scn: Scenario) -> SimResult:
    """Run one complete simulation; pure, jittable, vmappable."""
    pol = scn.policy
    cls, vms = scn.cloudlets, scn.vms
    max_steps = scn.max_steps if scn.max_steps > 0 else default_max_steps(scn)

    if scn.sweep_impl == "pallas":
        from repro.kernels import ops as _kops

        advance = _kops.advance_sweep
    else:
        advance = _advance_jnp

    stage_in = jnp.where(
        cls.input_mb > 0,
        cls.input_mb / jnp.maximum(vms.bw_mbps[cls.vm], 1e-6),
        0.0,
    )
    ready_t = cls.submit_t + stage_in

    def cond(st: SimState) -> Array:
        return (
            (st.step < max_steps)
            & (st.t < pol.horizon)
            & ~jnp.all(_done_or_doomed(scn, st))
        )

    def body(st: SimState) -> SimState:
        # --- Sensor tick (periodic stale-by-design load sensing, §2.3) ---
        tick_due = pol.federation & (st.t >= st.last_tick + pol.sensor_interval)
        st = st.replace(
            sensed_load=jnp.where(
                tick_due, provision.sense_load(scn, st), st.sensed_load
            ),
            last_tick=jnp.where(tick_due, st.t, st.last_tick),
        )

        # --- VM lifecycle: destroy-drained, then place due requests ---
        st = provision.release_done_vms(scn, st)
        st, _ = provision.provision_due_vms(scn, st)

        # --- the updateVMsProcessing sweep: rates for every task unit ---
        rate, vm_mips = policies.cloudlet_rates(scn, st)
        active = rate > 0

        # --- next event bound from non-completion sources ---
        unready = cls.exists & (ready_t > st.t)
        unplaced = vms.exists & ~st.vm_placed & ~st.vm_failed
        migrating = vms.exists & st.vm_placed & (st.vm_avail_t > st.t)
        next_tick = jnp.where(
            pol.federation, st.last_tick + pol.sensor_interval, INF
        )
        bound_t = jnp.minimum(
            jnp.minimum(_min_where(ready_t, unready),
                        _min_where(vms.request_t, unplaced)),
            jnp.minimum(_min_where(st.vm_avail_t, migrating),
                        jnp.minimum(next_tick, pol.horizon)),
        )
        bound_dt = jnp.maximum(bound_t - st.t, 0.0)

        # --- fused advance: completion min-reduce + work depletion ---
        dt, new_rem = advance(st.rem_mi, rate, active, bound_dt)
        t_next = st.t + dt

        newly_started = active & ~st.started
        newly_fin = active & (new_rem <= _eps_mi(cls.length_mi))
        new_rem = jnp.where(newly_fin, 0.0, new_rem)

        # --- market accrual over [t, t_next] (paper §3.3) ---
        dc_of_cl = st.vm_dc[cls.vm]
        run_cost = jnp.where(
            active, dt * scn.market.cost_per_cpu_sec[dc_of_cl], 0.0
        )
        io_mb = jnp.where(newly_started, cls.input_mb, 0.0) + jnp.where(
            newly_fin, cls.output_mb, 0.0
        )
        io_cost = io_mb * scn.market.cost_per_bw_mb[dc_of_cl]
        D = scn.hosts.n_dc
        dc_seg = jnp.clip(dc_of_cl, 0, D - 1)
        energy = st.energy_j
        if scn.power is not None:
            from repro.core import energy as energy_mod

            energy = energy + energy_mod.power_draw(scn, st) * dt
        st = st.replace(
            t=t_next,
            step=st.step + 1,
            rem_mi=new_rem,
            started=st.started | newly_started,
            start_t=jnp.where(newly_started, st.t, st.start_t),
            finish_t=jnp.where(newly_fin, t_next, st.finish_t),
            cpu_time=st.cpu_time + jnp.where(active, dt, 0.0),
            cpu_cost=st.cpu_cost.at[dc_seg].add(run_cost),
            bw_cost=st.bw_cost.at[dc_seg].add(io_cost),
            energy_j=energy,
        )
        return st

    st = jax.lax.while_loop(cond, body, init_state(scn))

    fin = policies.cloudlet_finished(st) & cls.exists
    tat = jnp.where(fin, st.finish_t - cls.submit_t, INF)
    n_fin = jnp.sum(fin.astype(jnp.int32))
    mean_tat = jnp.sum(jnp.where(fin, tat, 0.0)) / jnp.maximum(n_fin, 1)
    makespan = jnp.max(jnp.where(fin, st.finish_t, -INF), initial=-INF)
    total_cost = jnp.sum(st.cpu_cost + st.ram_cost + st.storage_cost + st.bw_cost)
    return SimResult(
        finish_t=st.finish_t,
        start_t=st.start_t,
        turnaround=tat,
        makespan=makespan,
        mean_turnaround=mean_tat,
        n_finished=n_fin,
        n_events=st.step,
        n_migrations=jnp.sum(st.vm_migrations),
        vm_placed=st.vm_placed,
        vm_dc=st.vm_dc,
        vm_failed=st.vm_failed,
        cpu_cost=st.cpu_cost,
        ram_cost=st.ram_cost,
        storage_cost=st.storage_cost,
        bw_cost=st.bw_cost,
        energy_j=st.energy_j,
        total_cost=total_cost,
        end_t=st.t,
    )


def simulate_trace(scn: Scenario, sample_ts: Array) -> tuple[SimResult, Array]:
    """Simulation + progress trace: fraction of work done per cloudlet at each
    ``sample_ts`` point.  Reconstructed exactly from start/finish times under
    the *observed* rate profile by re-running the clock forward between
    samples — used by the Figure 9/10 reproduction.

    Implementation: run the ordinary simulation to get exact event times is
    not enough to recover mid-flight progress, so this variant re-executes the
    loop with a bounded scan that additionally stops at every sample point.
    """
    ts = jnp.sort(sample_ts)
    bumped = scn.replace(
        cloudlets=scn.cloudlets,  # unchanged; samples only add clock stops
        max_steps=(scn.max_steps if scn.max_steps > 0 else default_max_steps(scn))
        + ts.shape[0]
        + 8,
    )
    pol = bumped.policy
    cls, vms = bumped.cloudlets, bumped.vms

    if bumped.sweep_impl == "pallas":
        from repro.kernels import ops as _kops

        advance = _kops.advance_sweep
    else:
        advance = _advance_jnp

    stage_in = jnp.where(
        cls.input_mb > 0,
        cls.input_mb / jnp.maximum(vms.bw_mbps[cls.vm], 1e-6),
        0.0,
    )
    ready_t = cls.submit_t + stage_in
    n_samples = ts.shape[0]
    progress0 = jnp.zeros((n_samples, cls.n_cloudlets), jnp.float32)

    def cond(carry):
        st, _, cursor = carry
        return (
            (st.step < bumped.max_steps)
            & ((st.t < pol.horizon) | (cursor < n_samples))
            & (~jnp.all(_done_or_doomed(bumped, st)) | (cursor < n_samples))
        )

    def body(carry):
        st, prog, cursor = carry
        tick_due = pol.federation & (st.t >= st.last_tick + pol.sensor_interval)
        st = st.replace(
            sensed_load=jnp.where(
                tick_due, provision.sense_load(bumped, st), st.sensed_load
            ),
            last_tick=jnp.where(tick_due, st.t, st.last_tick),
        )
        st = provision.release_done_vms(bumped, st)
        st, _ = provision.provision_due_vms(bumped, st)
        rate, _ = policies.cloudlet_rates(bumped, st)
        active = rate > 0

        unready = cls.exists & (ready_t > st.t)
        unplaced = vms.exists & ~st.vm_placed & ~st.vm_failed
        migrating = vms.exists & st.vm_placed & (st.vm_avail_t > st.t)
        next_tick = jnp.where(pol.federation, st.last_tick + pol.sensor_interval, INF)
        next_sample = jnp.where(cursor < n_samples, ts[jnp.minimum(cursor, n_samples - 1)], INF)
        bound_t = jnp.minimum(
            jnp.minimum(_min_where(ready_t, unready), _min_where(vms.request_t, unplaced)),
            jnp.minimum(
                jnp.minimum(_min_where(st.vm_avail_t, migrating), next_sample),
                jnp.minimum(next_tick, pol.horizon),
            ),
        )
        bound_dt = jnp.maximum(bound_t - st.t, 0.0)
        dt, new_rem = advance(st.rem_mi, rate, active, bound_dt)
        t_next = st.t + dt

        newly_started = active & ~st.started
        newly_fin = active & (new_rem <= _eps_mi(cls.length_mi))
        new_rem = jnp.where(newly_fin, 0.0, new_rem)

        at_sample = (cursor < n_samples) & (
            t_next >= ts[jnp.minimum(cursor, n_samples - 1)]
        )
        frac = 1.0 - new_rem / jnp.maximum(cls.length_mi, 1e-9)
        prog = jnp.where(
            at_sample,
            prog.at[jnp.minimum(cursor, n_samples - 1)].set(frac),
            prog,
        )
        cursor = cursor + at_sample.astype(jnp.int32)

        st = st.replace(
            t=t_next,
            step=st.step + 1,
            rem_mi=new_rem,
            started=st.started | newly_started,
            start_t=jnp.where(newly_started, st.t, st.start_t),
            finish_t=jnp.where(newly_fin, t_next, st.finish_t),
            cpu_time=st.cpu_time + jnp.where(active, dt, 0.0),
        )
        return st, prog, cursor

    st, prog, _ = jax.lax.while_loop(cond, body, (init_state(bumped), progress0, jnp.asarray(0, jnp.int32)))

    fin = policies.cloudlet_finished(st) & cls.exists
    tat = jnp.where(fin, st.finish_t - cls.submit_t, INF)
    n_fin = jnp.sum(fin.astype(jnp.int32))
    mean_tat = jnp.sum(jnp.where(fin, tat, 0.0)) / jnp.maximum(n_fin, 1)
    makespan = jnp.max(jnp.where(fin, st.finish_t, -INF), initial=-INF)
    total_cost = jnp.sum(st.cpu_cost + st.ram_cost + st.storage_cost + st.bw_cost)
    res = SimResult(
        finish_t=st.finish_t, start_t=st.start_t, turnaround=tat,
        makespan=makespan, mean_turnaround=mean_tat, n_finished=n_fin,
        n_events=st.step, n_migrations=jnp.sum(st.vm_migrations),
        vm_placed=st.vm_placed, vm_dc=st.vm_dc, vm_failed=st.vm_failed,
        cpu_cost=st.cpu_cost, ram_cost=st.ram_cost,
        storage_cost=st.storage_cost, bw_cost=st.bw_cost,
        energy_j=st.energy_j, total_cost=total_cost, end_t=st.t,
    )
    return res, prog
