"""Simulation drivers over the ``event_step`` kernel (paper §4.1).

CloudSim advances the world between *events*: rates are piecewise-constant,
so each ``updateVMsProcessing()`` sweep returns the next expected completion
time and the clock jumps straight to the earliest one.  The loop body lives
exactly once, in ``core/step.py``; this module provides the three drivers:

* ``simulate``          — ``lax.while_loop`` until horizon/completion; the
                          production path, pure/jittable/vmappable.
* ``simulate_trace``    — same loop with a ``TraceInstrument`` observer
                          attached: per-cloudlet progress at sample times,
                          reconstructed *exactly* by interpolation under the
                          piecewise-constant rates — the event stream (and so
                          every ``SimResult`` field, including cost/energy)
                          is bit-identical to ``simulate``.
* ``simulate_history``  — fixed-length ``lax.scan`` emitting the full
                          per-event log (time, kind, per-DC utilization /
                          cost / energy snapshots): the scenario-analysis
                          surface for Figure 9/10-style timelines.

Equivalence argument (DESIGN.md §2): for CloudSim's model class — linear
work depletion under piecewise-constant allocations, with all state changes
triggered by the event kinds in step.py — jumping to the min of those bounds
and re-running the two-level policy sweep produces the same trajectory as
SimJava's event queue, without materializing a queue at all.

The whole loop is jittable, differentiable in the rates (not used), and
vmappable: a *campaign* of thousands of simulations runs as one program
(see campaign.py), which is this paper's "repeatable, free-of-cost
experimentation" scaled to a pod.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

from repro.core import step as step_mod
from repro.core.entities import (
    INF,
    Scenario,
    SimResult,
    SimState,
)
from repro.core.pytree import pytree_dataclass
from repro.core.step import (  # re-exported: the kernel surface
    Instrument,
    StepContext,
    StepEvent,
    TraceInstrument,
    UtilizationTimelineInstrument,
    default_max_steps,
    event_step,
    finalize_result,
    make_context,
)


def init_state(scn: Scenario) -> SimState:
    hosts, vms, cls = scn.hosts, scn.vms, scn.cloudlets
    D, H = hosts.cores.shape
    V, C = vms.n_vms, cls.n_cloudlets
    f32, i32 = jnp.float32, jnp.int32
    ready0 = jnp.where(cls.vm >= 0, step_mod.ready_times(scn), INF)
    if scn.topology is not None:
        # network stage-ins (input_dc >= 0) wait for the transfer phase to
        # open them on the link ledger (DESIGN.md §13); an idle ledger grants
        # each link its full bandwidth to its first transfer
        ready0 = jnp.where(cls.input_dc >= 0, INF, ready0)
        link_share0 = jnp.asarray(scn.topology.bw_mbps, f32)
    else:
        link_share0 = jnp.zeros((D, D), f32)
    return SimState(
        t=jnp.asarray(0.0, f32),
        step=jnp.asarray(0, i32),
        vm_host=jnp.full((V,), -1, i32),
        vm_dc=vms.dc.astype(i32),
        vm_placed=jnp.zeros((V,), bool),
        vm_failed=jnp.zeros((V,), bool),
        vm_evicted=jnp.zeros((V,), bool),
        vm_avail_t=jnp.full((V,), INF, f32),
        vm_released=jnp.zeros((V,), bool),
        vm_migrations=jnp.zeros((V,), i32),
        vm_mig_src=jnp.full((V,), -1, i32),
        pool_active=jnp.zeros((V,), bool),
        # a schedule that starts down (fail_t[k] <= 0) flips this at the
        # first event, before anything is placed
        host_up=jnp.asarray(hosts.exists),
        free_ram=jnp.where(hosts.exists, hosts.ram_mb, 0.0),
        free_storage=jnp.where(hosts.exists, hosts.storage_mb, 0.0),
        free_bw=jnp.where(hosts.exists, hosts.bw_mbps, 0.0),
        free_cores=jnp.where(hosts.exists, hosts.cores.astype(f32), 0.0),
        free_kv=jnp.where(hosts.exists, hosts.kv_blocks, 0.0),
        cl_vm=cls.vm.astype(i32),
        cl_ready_t=ready0,
        cl_admitted=jnp.zeros((C,), bool),
        cl_kv=jnp.zeros((C,), f32),
        rem_mi=jnp.where(cls.exists, cls.length_mi, 0.0),
        cl_rollback_mi=jnp.zeros((C,), f32),
        started=jnp.zeros((C,), bool),
        start_t=jnp.full((C,), INF, f32),
        finish_t=jnp.where(cls.exists, INF, -INF),  # ghosts count as finished
        cpu_time=jnp.zeros((C,), f32),
        sensed_load=jnp.zeros((D,), f32),
        last_tick=jnp.asarray(0.0, f32),
        cpu_cost=jnp.zeros((D,), f32),
        ram_cost=jnp.zeros((D,), f32),
        storage_cost=jnp.zeros((D,), f32),
        bw_cost=jnp.zeros((D,), f32),
        energy_j=jnp.zeros((D,), f32),
        vm_downtime=jnp.zeros((V,), f32),
        n_evacuations=jnp.asarray(0, i32),
        link_busy=jnp.zeros((D, D), i32),
        link_share=link_share0,
        vm_xfer_src=jnp.full((V,), -1, i32),
        vm_xfer_dst=jnp.full((V,), -1, i32),
        vm_xfer_rem=jnp.zeros((V,), f32),
        vm_xfer_share=jnp.zeros((V,), f32),
        cl_xfer_dst=jnp.full((C,), -1, i32),
        cl_xfer_rem=jnp.zeros((C,), f32),
        cl_xfer_share=jnp.zeros((C,), f32),
    )


def is_batched(scn: Scenario) -> bool:
    """Batch-major detection by rank (DESIGN.md §10): ``hosts.cores`` is
    ``[D, H]`` for one scenario, ``[B, D, H]`` for a stacked campaign.
    Under ``jax.vmap`` the per-row view is rank-2 again, so
    ``vmap(simulate)`` still composes with the single-scenario path — which
    is what keeps it an honest baseline for the batch-major drivers."""
    return jnp.ndim(scn.hosts.cores) == 3


def scenario_row(scn: Scenario, i: int = 0) -> Scenario:
    """One row of a stacked campaign (static fields pass through)."""
    return jax.tree.map(lambda x: x[i], scn)


def simulate_instrumented(
    scn: Scenario, extra_instruments: tuple = ()
) -> tuple[SimResult, dict]:
    """Run one simulation and collect instrument outputs (by instrument name).

    Instruments = step defaults + ``Scenario.instruments`` + ``extra_instruments``.
    A stacked campaign (``is_batched``) routes through the batch-major step:
    one compiled loop advances every row natively, finished rows frozen by
    the live mask, per-row results bitwise those of the solo runs.
    """
    if is_batched(scn):
        return _simulate_instrumented_batch(scn, tuple(extra_instruments))
    ctx, aux0 = make_context(scn, tuple(extra_instruments))
    max_steps = step_mod.resolve_max_steps(scn, ctx.instruments)

    def cond(carry) -> Array:
        return step_mod.step_cond(scn, carry[0], max_steps)

    def body(carry):
        carry, _ = event_step(scn, carry, ctx)
        return carry

    st, aux = jax.lax.while_loop(cond, body, (init_state(scn), aux0))
    return finalize_result(scn, st), step_mod.finalize_outputs(scn, st, ctx, aux)


def _simulate_instrumented_batch(
    scn_b: Scenario, extras: tuple
) -> tuple[SimResult, dict]:
    """Batch-major driver: ``while any(live)`` over ``batch_event_step``.

    ``make_context`` / ``resolve_max_steps`` read only static shape and
    instrument-structure information, so the row-0 view stands in for every
    row (``stack_scenarios`` enforces static-field agreement).
    """
    scn0 = scenario_row(scn_b)
    ctx, _ = make_context(scn0, extras)
    max_steps = step_mod.resolve_max_steps(scn0, ctx.instruments)
    st0 = jax.vmap(init_state)(scn_b)
    aux0 = jax.vmap(lambda s: step_mod.init_aux(s, extras))(scn_b)

    def cond(carry) -> Array:
        return jnp.any(step_mod.batch_live(scn_b, carry[0], max_steps))

    def body(carry):
        carry, _, _ = step_mod.batch_event_step(
            scn_b, carry, ctx, extras, max_steps
        )
        return carry

    st, aux = jax.lax.while_loop(cond, body, (st0, aux0))
    res = jax.vmap(finalize_result)(scn_b, st)
    out = jax.vmap(
        lambda s, f, a: step_mod.finalize_outputs_for(s, f, a, extras)
    )(scn_b, st, aux)
    return res, out


def entry_points() -> dict:
    """The engine's public driver surface, by stable name.

    ``analysis/simlint.py`` traces exactly these (plus the campaign chunk
    runner, its shard_map-sharded twin, and the Pallas advance kernel) when
    verifying the structural invariants of the compiled program — a new
    driver added here is linted automatically.  ``simulate`` covers both
    engine paths: handed a stacked campaign it routes through
    ``batch_event_step`` (see ``is_batched``).
    """
    return {
        "simulate": simulate,
        "simulate_trace": simulate_trace,
        "simulate_history": simulate_history,
    }


def simulate(scn: Scenario) -> SimResult:
    """Run one complete simulation; pure, jittable, vmappable.

    A stacked campaign (leading scenario axis, see ``is_batched``) runs
    batch-major: one compiled step advances every row, with early-exit
    masking and batch-global phase skipping — same per-row results, bitwise
    (DESIGN.md §10).
    """
    res, _ = simulate_instrumented(scn)
    return res


def simulate_trace(scn: Scenario, sample_ts: Array) -> tuple[SimResult, Array]:
    """Simulation + progress trace: fraction of work done per cloudlet at each
    ``sample_ts`` point — used by the Figure 9/10 reproduction.

    The trace is a pure observer (``TraceInstrument``): rates are
    piecewise-constant, so mid-interval progress interpolates exactly and no
    extra clock stop is needed.  The returned ``SimResult`` is therefore
    bit-identical to ``simulate(scn)`` — cost and energy included.  Rows of
    the progress matrix follow ``sample_ts`` in ascending order.
    """
    ts = jnp.sort(jnp.asarray(sample_ts, jnp.float32))
    tracer = TraceInstrument(sample_ts=ts)
    res, out = simulate_instrumented(scn, (tracer,))
    return res, out["trace"]["progress"]


@pytree_dataclass
class History:
    """Fixed-length per-event log, leading axis = ``max_steps``.

    Rows past the simulation's end are zero-filled with ``valid=False`` and
    ``kind=-1`` (the fixed shape is what lets a campaign vmap histories).
    """

    t: Array            # [T] f32  clock after each event
    dt: Array           # [T] f32  interval length
    kind: Array         # [T] i32  step.K_* classification (-1: padding)
    valid: Array        # [T] bool event actually happened
    n_finished: Array   # [T] i32  cloudlets finished so far
    utilization: Array  # [T, D] f32 per-DC utilization during the interval
    cpu_cost: Array     # [T, D] f32 accrued CPU cost after the event
    bw_cost: Array      # [T, D] f32 accrued bandwidth cost after the event
    energy_j: Array     # [T, D] f32 accrued energy after the event


def simulate_history(scn: Scenario) -> tuple[SimResult, History]:
    """Run one simulation emitting the full per-event log.

    A fixed-length ``lax.scan`` over ``event_step``: iterations past the end
    carry the final state unchanged and emit invalid rows, so the result is
    bit-identical to ``simulate`` while exposing the whole trajectory — the
    scenario-analysis surface (per-DC utilization/cost/energy timelines) the
    while-loop drivers cannot produce.  A stacked campaign emits
    ``[T, B, ...]`` records through the batch-major step (rows frozen once
    finished, exactly like their solo logs).
    """
    from repro.core import energy as energy_mod
    from repro.core import policies

    if is_batched(scn):
        return _simulate_history_batch(scn)

    ctx, aux0 = make_context(scn)
    max_steps = step_mod.resolve_max_steps(scn, ctx.instruments)
    i32 = jnp.int32

    def body(carry, _):
        st, aux = carry
        live = step_mod.step_cond(scn, st, max_steps)
        (st2, aux2), ev = event_step(scn, (st, aux), ctx)
        util = energy_mod.dc_utilization(scn, st2, vm_mips=ev.vm_mips)
        n_fin = jnp.sum(
            (policies.cloudlet_finished(st2) & scn.cloudlets.exists).astype(i32)
        )
        rec = History(
            t=jnp.where(live, ev.t1, 0.0),
            dt=jnp.where(live, ev.dt, 0.0),
            kind=jnp.where(live, ev.kind, -1),
            valid=live,
            n_finished=jnp.where(live, n_fin, 0),
            utilization=jnp.where(live, util, 0.0),
            cpu_cost=jnp.where(live, st2.cpu_cost, 0.0),
            bw_cost=jnp.where(live, st2.bw_cost, 0.0),
            energy_j=jnp.where(live, st2.energy_j, 0.0),
        )
        carry = jax.tree.map(
            lambda a, b: jnp.where(live, a, b), (st2, aux2), (st, aux)
        )
        return carry, rec

    (st, _), hist = jax.lax.scan(
        body, (init_state(scn), aux0), None, length=max_steps
    )
    return finalize_result(scn, st), hist


def _simulate_history_batch(scn_b: Scenario) -> tuple[SimResult, History]:
    """Batch-major history: fixed-length scan over ``batch_event_step``;
    ``History`` leaves get a ``[T, B, ...]`` layout."""
    from repro.core import energy as energy_mod
    from repro.core import policies

    scn0 = scenario_row(scn_b)
    ctx, _ = make_context(scn0)
    max_steps = step_mod.resolve_max_steps(scn0, ctx.instruments)
    st0 = jax.vmap(init_state)(scn_b)
    aux0 = jax.vmap(step_mod.init_aux)(scn_b)
    i32 = jnp.int32

    def body(carry, _):
        carry, ev, live = step_mod.batch_event_step(
            scn_b, carry, ctx, (), max_steps
        )
        st2 = carry[0]

        def record(scn, st_r, ev_r, live_r):
            util = energy_mod.dc_utilization(scn, st_r, vm_mips=ev_r.vm_mips)
            n_fin = jnp.sum(
                (policies.cloudlet_finished(st_r)
                 & scn.cloudlets.exists).astype(i32)
            )
            return History(
                t=jnp.where(live_r, ev_r.t1, 0.0),
                dt=jnp.where(live_r, ev_r.dt, 0.0),
                kind=jnp.where(live_r, ev_r.kind, -1),
                valid=live_r,
                n_finished=jnp.where(live_r, n_fin, 0),
                utilization=jnp.where(live_r, util, 0.0),
                cpu_cost=jnp.where(live_r, st_r.cpu_cost, 0.0),
                bw_cost=jnp.where(live_r, st_r.bw_cost, 0.0),
                energy_j=jnp.where(live_r, st_r.energy_j, 0.0),
            )

        return carry, jax.vmap(record)(scn_b, st2, ev, live)

    (st, _), hist = jax.lax.scan(body, (st0, aux0), None, length=max_steps)
    return jax.vmap(finalize_result)(scn_b, st), hist
