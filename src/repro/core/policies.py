"""Two-level space/time-shared scheduling (paper §3.2, Figure 4).

CloudSim schedules at two levels, each independently space- or time-shared:

* **host -> VM** (``VMMAllocationPolicy``): how a host's cores are granted to
  the VMs placed on it.
* **VM -> cloudlet** (``VMScheduling``): how a VM's granted capacity is
  divided among its task units.

Both levels reduce to one statement: *given the entity set, produce a MIPS
rate vector*.  Rates are piecewise-constant between events, so the engine
advances all work with ``rem -= rate * dt`` — this function pair IS the
paper's ``updateVMsProcessing()``/``updateGridletsProcessing()`` sweep,
re-derived as dataflow.

Space-shared = FCFS core occupancy (exclusive, queue otherwise) — Figure 4a/c.
Time-shared  = proportional share of capacity, capped at demand — Figure 4b/d.

Both variants are always computed and selected with ``where`` on the traced
policy flag, so a single compilation serves all four Figure-4 combinations
and campaigns may vmap over policies.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import Array

from repro.core.entities import INF, TIME_SHARED, Scenario, SimState
from repro.core import segments


def cloudlet_ready(scn: Scenario, state: SimState) -> Array:
    """[C] bool — dispatched and staged-in (SANStorage input transfer done).

    ``cl_ready_t`` is state, not schedule: fixed-binding rows carry their
    precomputed submit + stage-in time from ``init_state``; service-routed
    rows hold INF until the broker dispatches them (step.py).
    """
    return (state.t >= state.cl_ready_t) & scn.cloudlets.exists


def cloudlet_finished(state: SimState) -> Array:
    return state.finish_t < INF / 2


def vm_done(scn: Scenario, state: SimState) -> Array:
    """[V] bool — VM has work assigned and all of it has finished.

    A "done" VM releases its cores (CloudSim destroys VMs whose workload
    completed) — this is what lets Figure 4a's VM2 start after VM1 drains.
    VMs with no cloudlets idle forever (broker never destroys them here).

    Two auto-scaling refinements (DESIGN.md §7): while any service-routed
    cloudlet is still undispatched, no VM is done — every eligible VM is a
    potential dispatch target, and destroying drained VMs could leave a late
    service burst with an empty fleet (service rows would never run).  The
    cost is deliberate: in mixed fixed+service scenarios, a drained
    fixed-binding VM holds its slot until the last service row dispatches.
    And pool VMs are destroyed only by the autoscaler's scale-down
    (``provision.release_pool_vms``, which returns the row to the inactive
    pool state so it can be re-activated later), never by workload drain —
    an idle pool VM holds its slot until utilization says otherwise.
    """
    V = scn.vms.n_vms
    assigned = state.cl_vm >= 0
    cl_fin = cloudlet_finished(state) | ~scn.cloudlets.exists
    seg = jnp.where(scn.cloudlets.exists & assigned, state.cl_vm, V)
    all_fin = segments.segment_all(cl_fin, seg, V)
    has_work = segments.segment_sum(
        (scn.cloudlets.exists & assigned).astype(jnp.float32), seg, V
    ) > 0
    pending = jnp.any(scn.cloudlets.exists & ~assigned)
    done = has_work & all_fin & ~pending
    return jnp.where(scn.vms.pool, state.vm_released, done)


def sla_violation_mask(scn: Scenario, state: SimState) -> Array:
    """[C] bool — existing cloudlet with a real deadline (< INF) that
    finished past it, or never finished at all (finish_t stuck at INF).

    The SLA ledger of DESIGN.md §9: ``finalize_result`` sums this into
    ``SimResult.sla_violations``, so vmapped campaigns get per-row violation
    counts for MTBF x ckpt x policy grids with no post-processing.
    """
    cls = scn.cloudlets
    return (
        cls.exists
        & (cls.deadline < INF / 2)
        & (state.finish_t > cls.deadline)
    )


def vm_outstanding_mi(scn: Scenario, state: SimState) -> Array:
    """[V] assigned-but-unfinished remaining MI per VM.

    The broker's dispatch load key and the migration policies' "how much work
    rides on this VM" signal share this reduction.
    """
    V = scn.vms.n_vms
    seg = jnp.where(scn.cloudlets.exists & (state.cl_vm >= 0), state.cl_vm, V)
    return segments.segment_sum(
        jnp.where(cloudlet_finished(state), 0.0, state.rem_mi), seg, V
    )


def vm_demand_mips(scn: Scenario, state: SimState) -> Array:
    """[V] MIPS demanded right now: each ready, unfinished cloudlet wants
    ``cores`` of its VM's per-core MIPS whether or not the host throttles it
    (queued work counts fully — run-queue pressure, DESIGN.md §7/§8).
    """
    cls, vms = scn.cloudlets, scn.vms
    V = vms.n_vms
    want = cls.exists & cloudlet_ready(scn, state) & ~cloudlet_finished(state)
    seg = jnp.where(want & (state.cl_vm >= 0), state.cl_vm, V)
    cores = segments.segment_sum(
        jnp.where(want, cls.cores.astype(jnp.float32), 0.0), seg, V
    )
    return cores * vms.mips


def host_level_mips(scn: Scenario, state: SimState) -> Array:
    """[V] f32 — total MIPS each VM is granted by its host right now."""
    hosts, vms = scn.hosts, scn.vms
    D, H = hosts.cores.shape
    n_seg = D * H

    done = vm_done(scn, state)
    # Occupying: holds cores at its host (even while the image is migrating —
    # the slot is reserved from placement). Usable: may actually execute.
    occupying = state.vm_placed & ~done & vms.exists
    usable = occupying & (state.t >= state.vm_avail_t)

    seg = jnp.where(occupying, state.vm_dc * H + state.vm_host, n_seg)
    host_cores_v = hosts.cores[state.vm_dc, state.vm_host].astype(jnp.float32)
    host_mips_v = hosts.mips[state.vm_dc, state.vm_host]
    vm_cores_f = vms.cores.astype(jnp.float32)

    # --- space-shared (Fig 4a): FCFS exclusive core grants ---
    demand_cores = jnp.where(occupying, vm_cores_f, 0.0)
    prefix = segments.segment_prefix_sum(demand_cores, seg, n_seg)
    fits = prefix + vm_cores_f <= host_cores_v + 1e-6
    percore = jnp.minimum(vms.mips, host_mips_v)
    space = jnp.where(usable & fits, vm_cores_f * percore, 0.0)

    # --- time-shared (Fig 4c): proportional share of host capacity ---
    demand_mips = jnp.where(occupying, vm_cores_f * vms.mips, 0.0)
    total = segments.segment_sum(demand_mips, seg, n_seg)
    cap = (hosts.cores.astype(jnp.float32) * hosts.mips).reshape(-1)
    seg_safe = jnp.clip(seg, 0, n_seg - 1)
    total_v = total[seg_safe]
    scale = jnp.where(
        total_v > 0, jnp.minimum(1.0, cap[seg_safe] / jnp.maximum(total_v, 1e-9)), 0.0
    )
    time = jnp.where(usable, vm_cores_f * vms.mips * scale, 0.0)

    return jnp.where(scn.policy.host_policy == TIME_SHARED, time, space)


def cloudlet_rates(scn: Scenario, state: SimState) -> tuple[Array, Array]:
    """([C] MIPS rate per cloudlet, [V] granted VM MIPS).

    The per-cloudlet rate is *per required core* x cores, i.e. a 2-core
    cloudlet of length L finishes after L/(rate/cores) seconds of per-core
    progress; the engine tracks per-core remaining MI so dt = rem / (rate/cores).
    To keep the engine uniform we return the rate already normalized to
    per-core progress MIPS: rem_mi decreases at ``rate`` MI/s.
    """
    cls, vms = scn.cloudlets, scn.vms
    V = vms.n_vms

    vm_mips = host_level_mips(scn, state)

    # The effective binding: fixed rows carry their Cloudlets.vm from init,
    # service rows the broker's dispatch choice (undispatched rows are not
    # ready, so the clipped gather below never grants them capacity).
    vmi = jnp.clip(state.cl_vm, 0, V - 1)

    ready = cloudlet_ready(scn, state)
    fin = cloudlet_finished(state)
    occ = ready & ~fin & scn.cloudlets.exists
    # Serving rows (prompt_tokens > 0) are scheduled by the continuous-batch
    # model below, never by the Figure-4 pair; excluding them here keeps them
    # out of the legacy core-occupancy reductions.  Non-serving scenarios
    # have the mask all-False, so occ_leg == occ bitwise.
    is_serving = cls.prompt_tokens > 0.0
    occ_leg = occ & ~is_serving
    seg = jnp.where(occ_leg, vmi, V)
    cl_cores_f = cls.cores.astype(jnp.float32)
    vm_cores_f = jnp.maximum(vms.cores.astype(jnp.float32), 1.0)

    percore_capacity = vm_mips / vm_cores_f              # [V] MIPS per granted core

    # --- space-shared inside the VM (Fig 4a/b upper): FCFS core occupancy ---
    prefix = segments.segment_prefix_sum(
        jnp.where(occ_leg, cl_cores_f, 0.0), seg, V)
    fits = prefix + cl_cores_f <= vms.cores[vmi].astype(jnp.float32) + 1e-6
    space = jnp.where(occ_leg & fits, percore_capacity[vmi], 0.0)

    # --- time-shared inside the VM (Fig 4b/d): equal per-core share ---
    total_demand = segments.segment_sum(
        jnp.where(occ_leg, cl_cores_f, 0.0), seg, V)
    denom = jnp.maximum(total_demand, vms.cores.astype(jnp.float32))
    share = vm_mips / jnp.maximum(denom, 1e-9)           # per demanded core
    time = jnp.where(occ_leg, share[vmi], 0.0)

    rate = jnp.where(scn.policy.vm_policy == TIME_SHARED, time, space)

    # --- continuous-batching decode (DESIGN.md §14) ---
    # An admitted serving row decodes as a member of its VM's batch: per-step
    # rate is the per-core capacity degraded by 1 / (1 + alpha * (b - 1)) for
    # a decode batch of b.  A row awaiting KV-block admission makes no
    # progress.  All-False masks keep non-serving scenarios bitwise.
    occ_srv = occ & is_serving & state.cl_admitted
    seg_srv = jnp.where(occ_srv, vmi, V)
    batch = segments.segment_sum(occ_srv.astype(jnp.float32), seg_srv, V)
    slow = 1.0 + scn.policy.batch_degradation * jnp.maximum(batch - 1.0, 0.0)
    srv_rate = percore_capacity[vmi] / jnp.maximum(slow[vmi], 1e-9)
    rate = jnp.where(is_serving, jnp.where(occ_srv, srv_rate, 0.0), rate)

    # A cloudlet only runs while its VM is granted capacity.
    rate = jnp.where(vm_mips[vmi] > 0, rate, 0.0)
    return rate, vm_mips
