"""Scenario builders — including the paper's own experiments (§5).

* ``uniform_datacenter`` / ``build_scenario``: general constructor.
* ``fig4_scenario``: the 2-core host / 2 VMs / 8 task-units illustration.
* ``fig7_8_scenario``: instantiation scaling, 100 -> 100 000 hosts.
* ``fig9_10_scenario``: 10 000 hosts, 50 VMs, 500 cloudlets in groups of 50
  every 10 simulated minutes; space- vs time-shared cloudlet scheduling.
* ``table1_scenario``: 3 federated datacenters, migration on saturation.
* ``generated_scenario``: seeded dynamic workload (core/workload.py) over a
  fixed fleet — Poisson / diurnal / bursty arrival processes.
* ``autoscale_scenario``: bursty service-routed workload + a spare-VM pool
  driven by the threshold autoscaler (DESIGN.md §7).
* ``consolidation_scenario`` / ``balance_scenario``: runtime (live) VM
  migration across federated DCs — energy consolidation under an idle-gated
  power model, and load balancing with progress preservation (DESIGN.md §8).
* ``reliability_scenario`` / ``evacuation_scenario``: host failures under a
  seeded (or deterministic) outage schedule — checkpoint rollback, SLA
  deadlines, proactive pre-failure evacuation (DESIGN.md §9).

All static-workload builders produce numpy-backed pytrees; nothing touches
devices until the engine is jitted, so a 100k-host scenario costs megabytes
(Figure 8 redone).  The generator-backed builders take a ``jax.random`` key
and emit traced workloads, so campaigns vmap over seeds and rates.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.entities import (
    SPACE_SHARED,
    TIME_SHARED,
    Cloudlets,
    Hosts,
    Market,
    Policy,
    Scenario,
    VMRequests,
)

_F = np.float32
_I = np.int32


def make_policy(
    host_policy: int = SPACE_SHARED,
    vm_policy: int = SPACE_SHARED,
    federation: bool = False,
    core_reserving: bool = False,
    best_fit: bool = False,
    sensor_interval: float = 100.0,
    migration_fixed_s: float = 30.0,
    interdc_bw_mbps: float = 100.0,
    horizon: float = 1e7,
    autoscale: bool = False,
    scale_up_thresh: float = 0.75,
    scale_down_thresh: float = 0.0,
    live_migration: bool = False,
    migrate_balance_thresh: float = 1e9,
    migrate_consolidate_thresh: float = 0.0,
    ckpt_interval: float = 3.0e38,
    evacuation: bool = False,
    evac_lead_s: float = 60.0,
    locality_dispatch: bool = False,
    block_tokens: float = 16.0,
    batch_degradation: float = 0.0,
) -> Policy:
    """Build a ``Policy`` from Python values, casting every knob to its
    traced array dtype.

    Every field is simulation *data*, not jit metadata — which is why one
    compiled program serves a whole policy sweep (vmap/stack over policies,
    DESIGN.md §5) and why the search driver can change knob values between
    successive-halving rungs without recompiling.  See the field table in
    docs/API.md; defaults reproduce the paper's baseline configuration
    (space-shared at both levels, no federation/autoscale/migration/
    reliability machinery).
    """
    return Policy(
        host_policy=jnp.asarray(host_policy, jnp.int32),
        vm_policy=jnp.asarray(vm_policy, jnp.int32),
        federation=jnp.asarray(federation, bool),
        core_reserving=jnp.asarray(core_reserving, bool),
        best_fit=jnp.asarray(best_fit, bool),
        sensor_interval=jnp.asarray(sensor_interval, jnp.float32),
        migration_fixed_s=jnp.asarray(migration_fixed_s, jnp.float32),
        interdc_bw_mbps=jnp.asarray(interdc_bw_mbps, jnp.float32),
        horizon=jnp.asarray(horizon, jnp.float32),
        autoscale=jnp.asarray(autoscale, bool),
        scale_up_thresh=jnp.asarray(scale_up_thresh, jnp.float32),
        scale_down_thresh=jnp.asarray(scale_down_thresh, jnp.float32),
        live_migration=jnp.asarray(live_migration, bool),
        migrate_balance_thresh=jnp.asarray(
            migrate_balance_thresh, jnp.float32),
        migrate_consolidate_thresh=jnp.asarray(
            migrate_consolidate_thresh, jnp.float32),
        ckpt_interval=jnp.asarray(ckpt_interval, jnp.float32),
        evacuation=jnp.asarray(evacuation, bool),
        evac_lead_s=jnp.asarray(evac_lead_s, jnp.float32),
        locality_dispatch=jnp.asarray(locality_dispatch, bool),
        block_tokens=jnp.asarray(block_tokens, jnp.float32),
        batch_degradation=jnp.asarray(batch_degradation, jnp.float32),
    )


def uniform_hosts(
    n_dc: int,
    hosts_per_dc: int,
    cores: int = 1,
    mips: float = 1000.0,
    ram_mb: float = 1024.0,
    storage_mb: float = 2_000_000.0,
    bw_mbps: float = 1000.0,
    kv_blocks: float = 0.0,
    exists: np.ndarray | None = None,
) -> Hosts:
    """Homogeneous ``[n_dc, hosts_per_dc]`` host grid.

    ``exists`` masks rows out of a fixed-shape grid — ragged federations
    (DCs with different host counts) use one rectangular array plus the
    mask, never per-DC shapes (the fixed-shape rule, DESIGN.md §2).
    """
    shape = (n_dc, hosts_per_dc)
    ex = np.ones(shape, bool) if exists is None else exists
    return Hosts(
        cores=jnp.full(shape, cores, _I),
        mips=jnp.full(shape, mips, _F),
        ram_mb=jnp.full(shape, ram_mb, _F),
        storage_mb=jnp.full(shape, storage_mb, _F),
        bw_mbps=jnp.full(shape, bw_mbps, _F),
        kv_blocks=jnp.full(shape, kv_blocks, _F),
        exists=jnp.asarray(ex),
    )


def uniform_vms(
    n: int,
    dc: int | np.ndarray = 0,
    cores: int = 1,
    mips: float = 1000.0,
    ram_mb: float = 512.0,
    storage_mb: float = 1024.0,
    bw_mbps: float = 100.0,
    kv_blocks: float = 0.0,
    request_t: float | np.ndarray = 0.0,
    image_mb: float = 1024.0,
    pool: bool | np.ndarray = False,
) -> VMRequests:
    """``n`` identical VM requests; scalar args broadcast, arrays vary per VM.

    ``dc`` pins each request's home datacenter (federation may migrate it
    later); ``request_t`` staggers arrivals; ``pool=True`` rows are
    autoscaler-managed spares that start unprovisioned (step.py
    ``AutoscaleInstrument``).
    """
    return VMRequests(
        dc=jnp.broadcast_to(jnp.asarray(dc, _I), (n,)),
        cores=jnp.full((n,), cores, _I),
        mips=jnp.full((n,), mips, _F),
        ram_mb=jnp.full((n,), ram_mb, _F),
        storage_mb=jnp.full((n,), storage_mb, _F),
        bw_mbps=jnp.full((n,), bw_mbps, _F),
        kv_blocks=jnp.full((n,), kv_blocks, _F),
        request_t=jnp.broadcast_to(jnp.asarray(request_t, _F), (n,)),
        image_mb=jnp.full((n,), image_mb, _F),
        exists=jnp.ones((n,), bool),
        pool=jnp.broadcast_to(jnp.asarray(pool, bool), (n,)),
    )


def uniform_market(n_dc: int, cpu=3.0, ram=0.05, storage=0.001, bw=0.1) -> Market:
    """Per-DC resource prices (the paper's $/CPU-s, $/MB rates), identical
    across the federation; heterogeneous markets pass arrays directly to
    ``Market``."""
    return Market(
        cost_per_cpu_sec=jnp.full((n_dc,), cpu, _F),
        cost_per_ram_mb=jnp.full((n_dc,), ram, _F),
        cost_per_storage_mb=jnp.full((n_dc,), storage, _F),
        cost_per_bw_mb=jnp.full((n_dc,), bw, _F),
    )


def make_cloudlets(
    vm: np.ndarray,
    length_mi: np.ndarray,
    submit_t: np.ndarray,
    cores: np.ndarray | int = 1,
    input_mb: float | np.ndarray = 0.3,
    output_mb: float = 0.3,
    deadline: np.ndarray | float = 3.0e38,
    input_dc: int | np.ndarray = -1,
    prompt_tokens: float | np.ndarray = 0.0,
    max_new_tokens: float | np.ndarray = 0.0,
) -> Cloudlets:
    """Rows are re-sorted by (submit_t, row) — FCFS is row order downstream.

    ``deadline`` is the absolute SLA finish time (default INF: none).
    ``input_dc >= 0`` declares where the row's ``input_mb`` lives: the data
    must be staged to the assigned VM's datacenter before execution — a real
    fair-share link transfer under a ``Scenario.topology``, a flat
    ``interdc_bw_mbps`` divisor without one (default -1: VM-local stage-in,
    the legacy behavior)."""
    vm = np.asarray(vm, _I)
    n = vm.shape[0]
    length_mi = np.asarray(length_mi, _F)
    submit_t = np.broadcast_to(np.asarray(submit_t, _F), (n,))
    cores = np.broadcast_to(np.asarray(cores, _I), (n,))
    deadline = np.broadcast_to(np.asarray(deadline, _F), (n,))
    input_mb = np.broadcast_to(np.asarray(input_mb, _F), (n,))
    input_dc = np.broadcast_to(np.asarray(input_dc, _I), (n,))
    prompt_tokens = np.broadcast_to(np.asarray(prompt_tokens, _F), (n,))
    max_new_tokens = np.broadcast_to(np.asarray(max_new_tokens, _F), (n,))
    order = np.argsort(submit_t, kind="stable")
    return Cloudlets(
        vm=jnp.asarray(vm[order]),
        length_mi=jnp.asarray(length_mi[order]),
        cores=jnp.asarray(cores[order]),
        submit_t=jnp.asarray(submit_t[order]),
        input_mb=jnp.asarray(input_mb[order]),
        input_dc=jnp.asarray(input_dc[order]),
        output_mb=jnp.full((n,), output_mb, _F),
        deadline=jnp.asarray(deadline[order]),
        prompt_tokens=jnp.asarray(prompt_tokens[order]),
        max_new_tokens=jnp.asarray(max_new_tokens[order]),
        exists=jnp.ones((n,), bool),
    )


# ---------------------------------------------------------------------------
# Paper experiments
# ---------------------------------------------------------------------------

def fig4_scenario(host_policy: int, vm_policy: int, length_mi: float = 4000.0,
                  mips: float = 10.0) -> Scenario:
    """One 2-core host; VM1, VM2 each want 2 cores; 4 unit tasks per VM.

    Analytic completion times (L = length/mips per core-dedicated task):
      (a) space/space: VM1 tasks at L, 2L; VM2 tasks at 3L, 4L
      (b) space/time : VM1 all at 2L; VM2 all at 4L
      (c) time/space : both VMs: 2 tasks at 2L, 2 tasks at 4L
      (d) time/time  : all eight at 4L
    """
    hosts = uniform_hosts(1, 1, cores=2, mips=mips, ram_mb=4096.0)
    vms = uniform_vms(2, cores=2, mips=mips, ram_mb=1024.0)
    cl_vm = np.array([0, 0, 0, 0, 1, 1, 1, 1])
    cls = make_cloudlets(cl_vm, np.full(8, length_mi), np.zeros(8),
                         input_mb=0.0, output_mb=0.0)
    pol = make_policy(host_policy=host_policy, vm_policy=vm_policy)
    return Scenario(hosts=hosts, vms=vms, cloudlets=cls,
                    market=uniform_market(1), policy=pol)


def fig7_8_scenario(n_hosts: int) -> Scenario:
    """Instantiation-scaling environment: one DC, a broker, no workload."""
    hosts = uniform_hosts(1, n_hosts, cores=1, mips=1000.0,
                          ram_mb=1024.0, storage_mb=2_000_000.0)
    vms = uniform_vms(1)
    cls = make_cloudlets(np.array([0]), np.array([1.0]), np.array([0.0]),
                         input_mb=0.0, output_mb=0.0)
    return Scenario(hosts=hosts, vms=vms, cloudlets=cls,
                    market=uniform_market(1), policy=make_policy())


def fig9_10_scenario(vm_policy: int, n_hosts: int = 10_000, n_vms: int = 50,
                     n_groups: int = 10, group_interval_s: float = 600.0,
                     task_mi: float = 1_200_000.0) -> Scenario:
    """Paper §5 scheduling test: 10k hosts (1 core @1000 MIPS, 1 GB RAM, 2 TB),
    50 VMs (512 MB), 500 x 20-minute task units submitted 50-at-a-time every
    10 minutes; host-level policy space-shared with core reservation so each
    VM owns a host ("only one VM was allowed to be hosted in a host").
    """
    hosts = uniform_hosts(1, n_hosts, cores=1, mips=1000.0, ram_mb=1024.0,
                          storage_mb=2_000_000.0)
    vms = uniform_vms(n_vms, ram_mb=512.0, storage_mb=1024.0)
    n_cl = n_groups * n_vms
    cl_vm = np.tile(np.arange(n_vms), n_groups)
    submit = np.repeat(np.arange(n_groups) * group_interval_s, n_vms)
    cls = make_cloudlets(cl_vm, np.full(n_cl, task_mi), submit,
                         input_mb=0.3, output_mb=0.3)
    pol = make_policy(host_policy=SPACE_SHARED, vm_policy=vm_policy,
                      core_reserving=True)
    return Scenario(hosts=hosts, vms=vms, cloudlets=cls,
                    market=uniform_market(1), policy=pol)


def table1_scenario(federation: bool, n_dc: int = 3, hosts_per_dc: int = 10,
                    dc0_hosts: int = 7, n_vms: int = 25,
                    cloudlet_mi: float = 1_800_000.0,
                    peer_background: int = 5,
                    live_migration: bool = False,
                    migrate_balance_thresh: float = 1e9,
                    migrate_consolidate_thresh: float = 0.0) -> Scenario:
    """Federated 3-DC experiment (paper §5, Table 1).

    The paper's text under-specifies the saturation mechanism (its stated 50
    hosts/DC would absorb all 25 VMs with no contention at all), so we
    calibrate to the published *qualitative* claim — >50% mean-turnaround and
    ~20% makespan improvement.  Setup: DC0 has ``dc0_hosts`` single-core
    hosts, peers have ``hosts_per_dc`` with ``peer_background`` pre-existing
    idle VMs each (slots they hold).  All 25 user VMs land at DC0; the
    provisioner prefers free slots (origin, then least-loaded-peer iff
    federated — the CloudCoordinator rule) and otherwise time-share-stacks at
    the origin.  Without federation first-fit stacking packs hosts 4-deep
    (1024/256 MB) -> 7200 s tasks; with federation the overflow spreads over
    peer slots and lightly-stacked origin hosts.  See
    benchmarks/table1_federation.py for the measured table.

    ``live_migration=True`` additionally attaches the runtime
    ``MigrationInstrument`` with the given thresholds (DESIGN.md §8) — off by
    default, so the published Table-1 numbers are untouched.
    """
    exists = np.ones((n_dc, hosts_per_dc), bool)
    exists[0, dc0_hosts:] = False
    hosts = uniform_hosts(n_dc, hosts_per_dc, cores=1, mips=1000.0,
                          ram_mb=1024.0, storage_mb=2_000_000.0,
                          exists=exists)
    # Background VMs occupy slots on peer DCs (they idle: no cloudlets).
    n_bg = peer_background * (n_dc - 1)
    bg_dc = np.repeat(np.arange(1, n_dc), peer_background)
    total_vms = n_vms + n_bg
    vms = uniform_vms(
        total_vms,
        dc=np.concatenate([bg_dc, np.zeros(n_vms, int)]),
        ram_mb=256.0,
        storage_mb=1024.0,
        request_t=np.concatenate([np.full(n_bg, 0.0), np.full(n_vms, 1.0)]),
        image_mb=1024.0,
    )
    cl_vm = np.arange(n_bg, total_vms)
    cls = make_cloudlets(cl_vm, np.full(n_vms, cloudlet_mi),
                         np.full(n_vms, 1.0), input_mb=0.3, output_mb=0.3)
    pol = make_policy(
        host_policy=TIME_SHARED,
        vm_policy=TIME_SHARED,
        federation=federation,
        core_reserving=False,
        sensor_interval=50.0,
        migration_fixed_s=30.0,
        interdc_bw_mbps=100.0,
        horizon=50_000.0,
        live_migration=live_migration,
        migrate_balance_thresh=migrate_balance_thresh,
        migrate_consolidate_thresh=migrate_consolidate_thresh,
    )
    instruments = ()
    max_steps = 4 * (total_vms + n_vms) + 1200
    if live_migration:
        from repro.core.step import MigrationInstrument

        instruments = (MigrationInstrument(),)
        max_steps += 400   # migration arrivals on top of the tick budget
    return Scenario(hosts=hosts, vms=vms, cloudlets=cls,
                    market=uniform_market(n_dc),
                    policy=pol, instruments=instruments,
                    max_steps=max_steps)


# ---------------------------------------------------------------------------
# Generator-backed scenarios (dynamic workloads + auto-scaling, DESIGN.md §7)
# ---------------------------------------------------------------------------

def generated_scenario(key, kind: str = "poisson", n_cloudlets: int = 64,
                       n_vms: int = 8, n_hosts: int = 8, rate: float = 0.1,
                       median_mi: float = 30_000.0, mips: float = 1000.0,
                       vm_policy: int = SPACE_SHARED,
                       **gen_kw) -> Scenario:
    """A seeded dynamic workload (Poisson/diurnal/bursty) over a fixed fleet,
    routed round-robin — the paper's "varying load" without elasticity."""
    from repro.core import workload

    hosts = uniform_hosts(1, n_hosts, cores=1, mips=mips, ram_mb=1024.0,
                          storage_mb=2_000_000.0)
    vms = uniform_vms(n_vms, mips=mips, ram_mb=512.0, storage_mb=1024.0)
    cls = workload.generate_cloudlets(
        key, n_cloudlets, kind=kind, rate=rate, median_mi=median_mi,
        n_vms=n_vms, **gen_kw)
    pol = make_policy(host_policy=SPACE_SHARED, vm_policy=vm_policy,
                      core_reserving=True)
    return Scenario(hosts=hosts, vms=vms, cloudlets=cls,
                    market=uniform_market(1), policy=pol,
                    max_steps=4 * (n_cloudlets + n_vms) + 400)


def autoscale_scenario(key, *, n_base: int = 4, n_pool: int = 4,
                       n_cloudlets: int = 48, n_bursts: int = 3,
                       burst_rate: float = 0.1, off_gap_mean: float = 800.0,
                       median_mi: float = 60_000.0, sigma_mi: float = 0.3,
                       mips: float = 1000.0, autoscale: bool = True,
                       scale_up_thresh: float = 0.6,
                       scale_down_thresh: float = 0.0,
                       sensor_interval: float = 20.0,
                       boot_s: float = 30.0,
                       max_steps: int | None = None) -> Scenario:
    """Bursty service-routed workload + a spare-VM pool under the threshold
    autoscaler (DESIGN.md §7) — the abstract's "automatic scaling".

    One DC of ``n_base + n_pool`` single-core hosts; each VM owns a host
    (core-reserving space-shared).  Cloudlets are ``vm == -1``: the broker
    dispatches each arrival to the least-loaded active VM, so activated pool
    VMs actually absorb load.  Defaults overload the base fleet ~1.5x during
    a burst (16 jobs x 60s work arriving over ~160s across 4 base VMs), which
    the pool absorbs once demand stays over ``scale_up_thresh`` for a full
    sensor interval.  ``autoscale=False`` (or sweeping the traced policy
    flag) is the static-fleet control — same compilation either way.
    """
    from repro.core import workload

    n_vms = n_base + n_pool
    hosts = uniform_hosts(1, n_vms, cores=1, mips=mips, ram_mb=1024.0,
                          storage_mb=2_000_000.0)
    vms = uniform_vms(
        n_vms, mips=mips, ram_mb=512.0, storage_mb=1024.0,
        pool=np.arange(n_vms) >= n_base)
    cls = workload.generate_cloudlets(
        key, n_cloudlets, kind="bursty", n_bursts=n_bursts, rate=burst_rate,
        off_gap_mean=off_gap_mean, median_mi=median_mi, sigma_mi=sigma_mi,
        n_vms=None)
    pol = make_policy(
        host_policy=SPACE_SHARED, vm_policy=SPACE_SHARED,
        core_reserving=True, sensor_interval=sensor_interval,
        migration_fixed_s=boot_s, autoscale=autoscale,
        scale_up_thresh=scale_up_thresh, scale_down_thresh=scale_down_thresh)
    if max_steps is None:
        # arrivals + completions + lifecycle, plus one K_SCALE tick per
        # sensor interval over a generous estimate of the active span
        span = 2.0 * n_bursts * (
            off_gap_mean + n_cloudlets / n_bursts / burst_rate
        ) + 4.0 * median_mi / mips
        max_steps = 4 * (n_cloudlets + n_vms) + int(span / sensor_interval) + 200
    from repro.core.step import AutoscaleInstrument

    return Scenario(hosts=hosts, vms=vms, cloudlets=cls,
                    market=uniform_market(1), policy=pol,
                    instruments=(AutoscaleInstrument(),),
                    max_steps=max_steps)


# ---------------------------------------------------------------------------
# Runtime (live) migration scenarios (DESIGN.md §8)
# ---------------------------------------------------------------------------

def consolidation_scenario(*, n_spare: int = 4, n_tasks: int = 4,
                           task_mi: float = 500_000.0,
                           live_migration: bool = True,
                           consolidate_thresh: float = 0.5,
                           sensor_interval: float = 30.0,
                           migration_fixed_s: float = 30.0,
                           interdc_bw_mbps: float = 100.0,
                           horizon: float = 4000.0,
                           idle_w: float = 93.0,
                           peak_w: float = 135.0) -> Scenario:
    """Energy-consolidation demo: two federated DCs under an idle-gated power
    model (energy.PowerModel.gate_idle).

    DC0 runs the actual work — one big host (``1 + n_spare`` cores) hosting a
    single worker VM with ``n_tasks`` serial cloudlets.  DC1 holds
    ``n_spare`` *idle* VMs, one per single-core host, burning idle watts.
    With live migration on, the coordinator drains DC1's idle images into
    DC0's spare slots (one per sensor tick, idlest VM first), the emptied
    hosts power-gate to zero, and total energy drops measurably vs the
    no-migration control — which is the *same compiled program*, because
    ``Policy.live_migration`` is traced data a campaign can sweep.
    """
    from repro.core.energy import PowerModel
    from repro.core.step import MigrationInstrument

    D, H = 2, max(1, n_spare)
    exists = np.zeros((D, H), bool)
    exists[0, 0] = True
    exists[1, :n_spare] = True
    cores = np.ones((D, H), _I)
    cores[0, 0] = 1 + n_spare
    hosts = uniform_hosts(D, H, cores=1, mips=1000.0, ram_mb=8192.0,
                          storage_mb=2_000_000.0, exists=exists)
    hosts = hosts.replace(cores=jnp.asarray(cores))
    # row 0: the worker at DC0; rows 1..n_spare: idle images at DC1
    vms = uniform_vms(1 + n_spare, dc=np.array([0] + [1] * n_spare),
                      cores=1, mips=1000.0, ram_mb=256.0, storage_mb=1024.0,
                      image_mb=1024.0)
    cls = make_cloudlets(np.zeros(n_tasks, _I), np.full(n_tasks, task_mi),
                         np.zeros(n_tasks), input_mb=0.0, output_mb=0.0)
    pol = make_policy(
        host_policy=SPACE_SHARED, vm_policy=SPACE_SHARED,
        federation=True, sensor_interval=sensor_interval,
        migration_fixed_s=migration_fixed_s,
        interdc_bw_mbps=interdc_bw_mbps, horizon=horizon,
        live_migration=live_migration,
        migrate_consolidate_thresh=consolidate_thresh)
    max_steps = (4 * (n_tasks + 1 + n_spare)
                 + 2 * int(horizon / sensor_interval) + 100)
    return Scenario(hosts=hosts, vms=vms, cloudlets=cls,
                    market=uniform_market(D), policy=pol,
                    power=PowerModel.uniform(D, idle=idle_w, peak=peak_w,
                                             gate_idle=True),
                    instruments=(MigrationInstrument(),),
                    max_steps=max_steps)


# ---------------------------------------------------------------------------
# Reliability scenarios (host failures + SLA, DESIGN.md §9)
# ---------------------------------------------------------------------------

def reliability_scenario(key=None, *, n_dc: int = 2, hosts_per_dc: int = 3,
                         n_vms: int = 4, cl_per_vm: int = 2,
                         task_mi: float = 100_000.0, mips: float = 1000.0,
                         n_outages: int = 2, mtbf_s: float = 700.0,
                         mttr_s: float = 400.0,
                         ckpt_interval: float = 3.0e38,
                         evacuation: bool = False,
                         evac_lead_s: float = 40.0,
                         deadline_slack: float = 6.0,
                         federation: bool = True,
                         sensor_interval: float = 50.0,
                         migration_fixed_s: float = 30.0,
                         horizon: float = 20_000.0) -> Scenario:
    """Seeded host-failure scenario: a federated fleet under exponential
    MTBF/MTTR outages (``workload.host_outages``), per-cloudlet deadlines at
    ``deadline_slack`` x the ideal runtime, checkpoint rollback, and the
    proactive-evacuation coordinator (DESIGN.md §9).

    ``key=None`` (or ``mtbf_s >= INF``) yields the never-failing control
    with identical shapes, so an MTBF x ckpt x policy campaign vmaps the
    control and its failing peers through one compiled program.
    """
    from repro.core import workload
    from repro.core.step import ReliabilityInstrument

    hosts = uniform_hosts(n_dc, hosts_per_dc, cores=1, mips=mips,
                          ram_mb=1024.0, storage_mb=2_000_000.0)
    vms = uniform_vms(n_vms, dc=0, cores=1, mips=mips, ram_mb=512.0,
                      storage_mb=1024.0, image_mb=1024.0)
    n_cl = n_vms * cl_per_vm
    ideal_s = cl_per_vm * task_mi / mips
    cls = make_cloudlets(np.arange(n_cl) % n_vms, np.full(n_cl, task_mi),
                         np.zeros(n_cl), input_mb=0.0, output_mb=0.0,
                         deadline=deadline_slack * ideal_s)
    if key is None:
        outages = workload.no_outages(n_dc, hosts_per_dc, n_outages)
    else:
        outages = workload.host_outages(
            key, n_dc, hosts_per_dc, n_outages, mtbf_s, mttr_s)
    pol = make_policy(
        host_policy=SPACE_SHARED, vm_policy=SPACE_SHARED,
        core_reserving=True, federation=federation,
        sensor_interval=sensor_interval,
        migration_fixed_s=migration_fixed_s, horizon=horizon,
        ckpt_interval=ckpt_interval, evacuation=evacuation,
        evac_lead_s=evac_lead_s)
    n_out = n_dc * hosts_per_dc * n_outages
    max_steps = (4 * (n_cl + n_vms) + 4 * n_out + 4 * n_vms
                 + 2 * int(horizon / sensor_interval) + 200)
    return Scenario(hosts=hosts, vms=vms, cloudlets=cls,
                    market=uniform_market(n_dc), policy=pol,
                    outages=outages,
                    instruments=(ReliabilityInstrument(),),
                    max_steps=max_steps)


def evacuation_scenario(*, evacuation: bool = True,
                        ckpt_interval: float = 100_000.0,
                        fail_at: float = 300.0,
                        repair_after: float = 5000.0,
                        n_workers: int = 2,
                        task_mi: float = 600_000.0,
                        mips: float = 1000.0,
                        deadline: float = 800.0,
                        evac_lead_s: float = 50.0,
                        sensor_interval: float = 50.0,
                        migration_fixed_s: float = 30.0,
                        interdc_bw_mbps: float = 100.0,
                        horizon: float = 6000.0,
                        idle_w: float = 93.0,
                        peak_w: float = 135.0) -> Scenario:
    """Deterministic reliability demo: DC0's only host is scheduled to fail
    at ``fail_at``; DC1 holds exactly enough spare slots.

    With evacuation on, the coordinator drains every worker to DC1 at the
    ``evac_lead_s`` alarm — stop-and-copy, progress preserved — and each
    600s cloudlet finishes ~40s late: inside its ``deadline``, zero
    downtime.  The restart-from-zero control (``evacuation=False,
    ckpt_interval=INF``) loses ``fail_at`` seconds of work per cloudlet plus
    a recovery transfer it books as downtime, and misses every deadline —
    at the same energy order of magnitude, in the *same compiled program*
    (`evacuation`/`ckpt_interval` are traced policy data a campaign vmaps;
    benchmarks/reliability.py measures the grid).
    """
    from repro.core import workload
    from repro.core.energy import PowerModel
    from repro.core.step import ReliabilityInstrument

    hosts = uniform_hosts(2, 1, cores=n_workers, mips=mips, ram_mb=4096.0,
                          storage_mb=2_000_000.0)
    vms = uniform_vms(n_workers, dc=0, cores=1, mips=mips, ram_mb=256.0,
                      storage_mb=1024.0, image_mb=1024.0)
    cls = make_cloudlets(np.arange(n_workers), np.full(n_workers, task_mi),
                         np.zeros(n_workers), input_mb=0.0, output_mb=0.0,
                         deadline=deadline)
    outages = workload.no_outages(2, 1, 1)
    outages = outages.replace(
        fail_t=outages.fail_t.at[0, 0, 0].set(fail_at),
        repair_t=outages.repair_t.at[0, 0, 0].set(fail_at + repair_after),
    )
    pol = make_policy(
        host_policy=SPACE_SHARED, vm_policy=SPACE_SHARED,
        core_reserving=True, federation=True,
        sensor_interval=sensor_interval,
        migration_fixed_s=migration_fixed_s,
        interdc_bw_mbps=interdc_bw_mbps, horizon=horizon,
        ckpt_interval=ckpt_interval, evacuation=evacuation,
        evac_lead_s=evac_lead_s)
    max_steps = (4 * (2 * n_workers) + 2 * int(horizon / sensor_interval)
                 + 4 * n_workers + 100)
    return Scenario(hosts=hosts, vms=vms, cloudlets=cls,
                    market=uniform_market(2), policy=pol,
                    power=PowerModel.uniform(2, idle=idle_w, peak=peak_w),
                    outages=outages,
                    instruments=(ReliabilityInstrument(),),
                    max_steps=max_steps)


def balance_scenario(*, live_migration: bool = True,
                     balance_thresh: float = 1.5,
                     work_mi: float = 1_000_000.0,
                     bg_mi: float = 50_000.0,
                     sensor_interval: float = 100.0,
                     migration_fixed_s: float = 30.0,
                     interdc_bw_mbps: float = 100.0,
                     horizon: float = 10_000.0) -> Scenario:
    """Load-balancing demo: two single-host DCs; DC0 starts 2x oversubscribed.

    Two worker VMs time-share DC0's one core (500 MIPS each); DC1's host is
    held by a short-lived background VM that drains early.  At the first
    sensor tick after the slot frees, the coordinator sheds one worker —
    carrying its accrued progress — to DC1, and both cloudlets finish in
    roughly half the static control's makespan.  The improvement rule
    (DESIGN.md §8) then holds the 1.0/1.0 split stable: no ping-pong.
    """
    from repro.core.step import MigrationInstrument

    hosts = uniform_hosts(2, 1, cores=1, mips=1000.0, ram_mb=4096.0,
                          storage_mb=2_000_000.0)
    # row 0: background at DC1; rows 1-2: the oversubscribed workers at DC0
    vms = uniform_vms(3, dc=np.array([1, 0, 0]), cores=1, mips=1000.0,
                      ram_mb=256.0, storage_mb=1024.0, image_mb=1024.0)
    cls = make_cloudlets(np.array([0, 1, 2]),
                         np.array([bg_mi, work_mi, work_mi]),
                         np.zeros(3), input_mb=0.0, output_mb=0.0)
    pol = make_policy(
        host_policy=TIME_SHARED, vm_policy=SPACE_SHARED,
        federation=True, sensor_interval=sensor_interval,
        migration_fixed_s=migration_fixed_s,
        interdc_bw_mbps=interdc_bw_mbps, horizon=horizon,
        live_migration=live_migration,
        migrate_balance_thresh=balance_thresh)
    max_steps = 4 * (3 + 3) + 2 * int(horizon / sensor_interval) + 100
    return Scenario(hosts=hosts, vms=vms, cloudlets=cls,
                    market=uniform_market(2), policy=pol,
                    instruments=(MigrationInstrument(),),
                    max_steps=max_steps)


def staging_scenario(*, n_dc: int = 3, hosts_per_dc: int = 2,
                     vms_per_dc: int = 2, n_cloudlets: int = 48,
                     wave: int = 8, wave_dt: float = 2.0,
                     input_mb: float = 256.0, task_mi: float = 20_000.0,
                     bw_mbps: float = 100.0, latency_s: float = 0.05,
                     locality_dispatch: bool = False,
                     horizon: float = 1e6) -> Scenario:
    """Data-staging-heavy demo of the contention-aware network layer
    (DESIGN.md §13): service-routed cloudlets whose ``input_mb`` lives on a
    declared ``input_dc`` arrive in waves of ``wave``, so many stage-in
    transfers overlap on the inter-DC links and fair sharing governs every
    completion time.

    ``locality_dispatch`` flips the broker between least-loaded rank
    dispatch and the data-gravity score (queue depth + estimated transfer
    seconds at current link occupancy) inside one compiled program — the
    knob is traced, so a campaign sweeps it.
    """
    from repro.core.energy import Topology

    n_vms = n_dc * vms_per_dc
    hosts = uniform_hosts(n_dc, hosts_per_dc, cores=4, mips=1000.0,
                          ram_mb=8192.0, storage_mb=2_000_000.0)
    vms = uniform_vms(n_vms, dc=np.arange(n_vms) % n_dc, cores=1,
                      mips=1000.0, ram_mb=256.0, storage_mb=1024.0,
                      image_mb=1024.0)
    submit = (np.arange(n_cloudlets) // wave) * wave_dt
    cls = make_cloudlets(
        np.full(n_cloudlets, -1), np.full(n_cloudlets, task_mi), submit,
        input_mb=input_mb, output_mb=0.0,
        input_dc=np.arange(n_cloudlets) % n_dc,
    )
    pol = make_policy(horizon=horizon, interdc_bw_mbps=bw_mbps,
                      locality_dispatch=locality_dispatch)
    max_steps = 6 * n_cloudlets + 4 * n_vms + 300
    return Scenario(
        hosts=hosts, vms=vms, cloudlets=cls, market=uniform_market(n_dc),
        policy=pol,
        topology=Topology.uniform(n_dc, latency_s=latency_s,
                                  bw_mbps=bw_mbps),
        max_steps=max_steps,
    )


# ---------------------------------------------------------------------------
# LLM-serving scenario (KV-bound continuous batching, DESIGN.md §14)
# ---------------------------------------------------------------------------

def serving_scenario(key, *, n_requests: int = 64, n_replicas: int = 4,
                     n_pool: int = 0, kv_blocks: float = 64.0,
                     rate: float = 0.5, kind: str = "diurnal",
                     block_tokens: float = 16.0,
                     batch_degradation: float = 0.05,
                     mips: float = 1000.0, token_mi: float = 10.0,
                     median_prompt: float = 128.0, median_new: float = 64.0,
                     autoscale: bool = False,
                     scale_up_thresh: float = 0.75,
                     scale_down_thresh: float = 0.0,
                     sensor_interval: float = 50.0, boot_s: float = 30.0,
                     deadline_rel: float | None = None,
                     horizon: float = 1e6,
                     max_steps: int | None = None, **gen_kw) -> Scenario:
    """A simulated LLM-inference fleet: seeded diurnal/bursty request
    traffic over ``n_replicas`` serving replicas (one accelerator host
    each, ``kv_blocks`` KV-cache blocks), scheduled with KV-bound
    continuous batching (DESIGN.md §14).

    Requests are service-routed token-generation cloudlets
    (``workload.generate_serving_requests``): the broker spreads arrivals
    over replicas, each replica admits requests FCFS while their KV
    footprint fits its pool, decodes them as one batch whose per-request
    rate degrades by ``1/(1 + batch_degradation * (b - 1))``, and preempts
    youngest-first on block exhaustion (rollback to the last emitted
    token).  ``n_pool`` spare replicas ride the PR-3 threshold autoscaler
    (``autoscale`` gates it, traced); ``deadline_rel`` attaches per-request
    SLA deadlines so the PR-5 violation ledger scores tail latency.

    ``rate``, ``kv_blocks`` and the autoscale thresholds are traced data:
    one compiled program serves a rate x kv_blocks x threshold campaign
    (``broadcast_campaign`` + batch-major drivers), with TTFT/TPOT
    percentiles per row in the reduced ``SimResult``.
    """
    from repro.core import workload
    from repro.core.step import AutoscaleInstrument

    n_vms = n_replicas + n_pool
    hosts = uniform_hosts(1, n_vms, cores=1, mips=mips, ram_mb=8192.0,
                          storage_mb=2_000_000.0, kv_blocks=kv_blocks)
    vms = uniform_vms(n_vms, mips=mips, ram_mb=512.0, storage_mb=1024.0,
                      kv_blocks=kv_blocks,
                      pool=np.arange(n_vms) >= n_replicas)
    cls = workload.generate_serving_requests(
        key, n_requests, kind=kind, rate=rate, token_mi=token_mi,
        median_prompt=median_prompt, median_new=median_new,
        deadline_rel=deadline_rel, **gen_kw)
    pol = make_policy(
        host_policy=SPACE_SHARED, vm_policy=SPACE_SHARED,
        core_reserving=True, horizon=horizon,
        sensor_interval=sensor_interval, migration_fixed_s=boot_s,
        autoscale=autoscale, scale_up_thresh=scale_up_thresh,
        scale_down_thresh=scale_down_thresh,
        block_tokens=block_tokens, batch_degradation=batch_degradation)
    if max_steps is None:
        # arrivals/dispatch/completions + one K_SERVING stop per KV-block
        # boundary (~max_new/block_tokens per request, headroom for the
        # lognormal tail and preempt/re-admit churn) + autoscale ticks over
        # a generous active-span estimate.  Static Python ints only — the
        # traced knobs (rate, kv_blocks, thresholds) never enter here.
        try:
            rate_f = float(rate)
        except TypeError as exc:   # traced rate: the step budget must be given
            raise ValueError(
                "serving_scenario: pass max_steps explicitly when rate is "
                "traced (the step budget is static jit metadata)"
            ) from exc
        boundary = int(
            n_requests * (4.0 * median_new / max(block_tokens, 1.0) + 6.0))
        span = 2.0 * n_requests / max(rate_f, 1e-6) + (
            4.0 * n_requests * median_new * token_mi
            / (mips * max(n_replicas, 1)))
        max_steps = (4 * (n_requests + n_vms) + boundary
                     + int(span / sensor_interval) + 400)
    return Scenario(hosts=hosts, vms=vms, cloudlets=cls,
                    market=uniform_market(1), policy=pol,
                    instruments=(AutoscaleInstrument(),),
                    max_steps=max_steps)
