"""The event-step kernel: the simulator's event-loop body, exactly once.

``simulate``, ``simulate_trace`` and ``simulate_history`` (engine.py) are thin
drivers over one function — ``event_step`` — which advances the world by one
event batch:

    0. host failure/repair edges     (outage schedule: evict + roll back)
    1. instrument ``pre`` hooks      (Sensor tick lives here)
    2. VM lifecycle                  (release drained, place due requests)
    3. policy sweep                  (per-cloudlet MIPS rates)
    4. next-event bound              (ready / request / migration / failure /
                                      repair / instrument bounds / horizon)
    5. fused advance                 (min-time-to-completion + work depletion,
                                      jnp or Pallas — resolved once per driver)
    6. instrument ``post`` hooks     (market accrual, energy integration,
                                      trace sampling, custom observables)

Cross-cutting observables are **Instruments**: small pytrees with
``init / pre / bound / post / finalize`` hooks threaded through the loop as an
auxiliary carry.  The engine body knows nothing about federation sensing,
prices, power models or progress traces — each is one class below, and a new
observable (say, a per-DC utilization timeline for Figure 9/10-style plots)
is one more class, not an engine fork.  See DESIGN.md §2 for the equivalence
argument and §3 for the instrument contract.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import Array

from repro.core import kvserve, policies, provision, segments
from repro.kernels import ops as _kernel_ops
from repro.core.entities import (
    INF,
    Scenario,
    SimResult,
    SimState,
)
from repro.core.pytree import pytree_dataclass

# Event kinds recorded by ``StepEvent.kind`` / ``History.kind``.
K_COMPLETION = 0   # a cloudlet ran out of work
K_READY = 1        # a submitted cloudlet finished stage-in
K_VM_REQUEST = 2   # a broker VM request came due
K_MIGRATION = 3    # a VM creation/migration transfer completed
K_TICK = 4         # a federation Sensor refresh
K_INSTRUMENT = 5   # a custom instrument clock stop
K_HORIZON = 6      # the simulation horizon
K_SCALE = 7        # an autoscaler evaluation tick (AutoscaleInstrument)
K_FAILURE = 8      # a scheduled host failure (Scenario.outages)
K_REPAIR = 9       # a failed host came back (empty)
K_STAGE = 10       # a pending data stage-in became openable (topology only)
K_SERVING = 11     # a decoding request crossed a KV-block boundary (§14)

# Named scopes wrapping the phase-skip ``lax.cond``s.  The names land in the
# optimized HLO's op metadata (``op_name=.../phase_provision/cond``), which is
# how simlint rule R1 verifies the predicates survive XLA lowering as real
# ``conditional`` ops with branch computations — not flattened into ``select``
# (the vmap degradation that silently pays both branches, DESIGN.md §10/§11).
SCOPE_PROVISION = "phase_provision"
SCOPE_DISPATCH = "phase_dispatch"
SCOPE_TRANSFER = "phase_transfer"
SCOPE_SERVING = "phase_serving"
# SCOPE_TRANSFER only exists in programs traced with a topology attached;
# simlint's lint scenarios carry one so R1 covers all four phases.
PHASE_SCOPES = (SCOPE_PROVISION, SCOPE_DISPATCH, SCOPE_TRANSFER, SCOPE_SERVING)


def default_max_steps(scn: Scenario) -> int:
    """Safety bound on event batches: starts + finishes + VM lifecycle + slack.

    Federation scenarios add ~horizon/sensor_interval tick events; builders
    for those pass ``Scenario.max_steps`` explicitly.  An outage schedule
    adds its fail/repair edges plus per-edge eviction/evacuation slack
    (schedule *shapes* are static, so this stays a Python int).
    """
    extra = 0
    if scn.outages is not None:
        n_out = int(scn.outages.fail_t.size)
        extra = 4 * n_out + 2 * scn.vms.n_vms
    if scn.topology is not None:
        # network stage-ins add a K_STAGE open plus a K_READY arrival per
        # row, and fair-share recomputes can split previously-coincident
        # completions into separate events
        extra += 2 * scn.cloudlets.n_cloudlets
    return 4 * (scn.cloudlets.n_cloudlets + scn.vms.n_vms) + 260 + extra


def resolve_max_steps(scn: Scenario, instruments: tuple = ()) -> int:
    """Driver step budget: scenario override or derived bound, plus whatever
    the attached instruments declare via ``Instrument.extra_steps``."""
    base = scn.max_steps if scn.max_steps > 0 else default_max_steps(scn)
    return base + sum(ins.extra_steps(scn) for ins in instruments)


def resolve_advance(scn: Scenario) -> Callable:
    """Choose the advance-sweep implementation once per driver (DESIGN.md §4).

    The kernels import happens at module scope, NOT here: importing a module
    mid-trace would create its module-level jnp constants under the active
    jit trace and leak tracers into later compilations.
    """
    return _kernel_ops.resolve_advance(scn.sweep_impl)


def _eps_mi(length_mi: Array) -> Array:
    """Finish tolerance: float32 work counters drift ~ulp per event (DESIGN.md
    §2, "f64-free"); tests bound the induced completion-time error."""
    return 1e-5 * length_mi + 0.25


def _min_where(x: Array, mask: Array) -> Array:
    return jnp.min(jnp.where(mask, x, INF), initial=INF)


def _done_or_doomed(scn: Scenario, st: SimState) -> Array:
    fin = policies.cloudlet_finished(st)
    assigned = st.cl_vm >= 0
    doomed = assigned & st.vm_failed[jnp.clip(st.cl_vm, 0, scn.vms.n_vms - 1)]
    return fin | doomed | ~scn.cloudlets.exists


def step_cond(scn: Scenario, st: SimState, max_steps: int) -> Array:
    """The loop-continuation predicate shared by every driver."""
    return (
        (st.step < max_steps)
        & (st.t < scn.policy.horizon)
        & ~jnp.all(_done_or_doomed(scn, st))
    )


def ready_times(scn: Scenario) -> Array:
    """[C] submit + SAN stage-in: when each cloudlet may start executing.

    Only meaningful for fixed-binding rows (``vm >= 0``); ``init_state`` sets
    service-routed rows to INF until the broker dispatches them, at which
    point the stage-in clock starts against the assigned VM's bandwidth.

    ``input_dc >= 0`` rows staging from a remote DC bill the flat
    ``interdc_bw_mbps`` divisor here; under a topology ``init_state``
    overrides them to INF and the transfer phase prices the move on the link
    ledger instead (DESIGN.md §13).
    """
    cls, vms = scn.cloudlets, scn.vms
    vmi = jnp.clip(cls.vm, 0, vms.n_vms - 1)
    stage_in = jnp.where(
        cls.input_mb > 0,
        cls.input_mb / jnp.maximum(vms.bw_mbps[vmi], 1e-6),
        0.0,
    )
    if scn.topology is None:
        remote = (cls.input_dc >= 0) & (cls.input_dc != vms.dc[vmi])
        stage_in = jnp.where(
            remote,
            cls.input_mb / jnp.maximum(scn.policy.interdc_bw_mbps, 1e-6),
            stage_in,
        )
    return cls.submit_t + stage_in


@pytree_dataclass
class StepEvent:
    """What one ``event_step`` emitted — everything instruments may observe.

    Rates are piecewise-constant over ``[t0, t1)`` (DESIGN.md §2), so any
    linear observable integrates exactly from these fields alone.
    """

    t0: Array              # scalar f32: interval start (clock before the step)
    t1: Array              # scalar f32: interval end (clock after the step)
    dt: Array              # scalar f32: t1 - t0
    kind: Array            # scalar i32: K_* event classification
    rate: Array            # [C] f32  per-cloudlet MIPS during the interval
    active: Array          # [C] bool executing during the interval
    rem_before: Array      # [C] f32  remaining MI at t0
    newly_started: Array   # [C] bool first granted capacity this step
    newly_finished: Array  # [C] bool depleted their work this step
    vm_mips: Array         # [V] f32  host-level granted MIPS during the interval


class Instrument:
    """Base observable: override any subset of the five hooks.

    ``aux`` is an arbitrary pytree threaded through the loop (the instrument's
    private state); hooks must be pure and shape-stable.  ``pre`` may rewrite
    ``SimState`` before the policy sweep, ``bound`` contributes an absolute
    next-event time (a clock stop), ``post`` observes the emitted ``StepEvent``
    after the state update, ``finalize`` turns the final aux into outputs.
    """

    name: str = "instrument"
    bound_kind: int = K_INSTRUMENT

    def init(self, scn: Scenario):
        return ()

    def extra_steps(self, scn: Scenario) -> int:
        """Static add-on to the driver's ``max_steps`` safety bound.

        An instrument whose ``bound()`` adds clock stops creates events the
        default bound (starts/finishes/lifecycle) does not count; override
        this with a concrete int so the loop cannot silently truncate.
        (Traced quantities — e.g. horizon/period with a traced horizon —
        cannot be counted here; set ``Scenario.max_steps`` explicitly then,
        as the federation builders do for Sensor ticks.)
        """
        return 0

    def pre(self, scn: Scenario, st: SimState, aux):
        return st, aux

    def bound(self, scn: Scenario, st: SimState, aux) -> Array:
        return INF

    def post(self, scn: Scenario, st: SimState, ev: StepEvent, aux):
        return st, aux

    def finalize(self, scn: Scenario, st: SimState, aux) -> dict:
        return {}


@pytree_dataclass
class SensorInstrument(Instrument):
    """Periodic stale-by-design load sensing (paper §2.3, the CIS Sensor).

    ``pre``: refresh ``sensed_load`` when a tick is due.  ``bound``: the next
    tick is a clock stop so the loop never jumps across a refresh.
    """

    # class attrs, unannotated on purpose: not dataclass/pytree fields
    name = "sensor"
    bound_kind = K_TICK

    def pre(self, scn: Scenario, st: SimState, aux):
        pol = scn.policy
        tick_due = pol.federation & (st.t >= st.last_tick + pol.sensor_interval)
        st = st.replace(
            sensed_load=jnp.where(
                tick_due, provision.sense_load(scn, st), st.sensed_load
            ),
            last_tick=jnp.where(tick_due, st.t, st.last_tick),
        )
        return st, aux

    def bound(self, scn: Scenario, st: SimState, aux) -> Array:
        pol = scn.policy
        return jnp.where(pol.federation, st.last_tick + pol.sensor_interval, INF)


@pytree_dataclass
class MarketInstrument(Instrument):
    """Per-interval market accrual (paper §3.3): CPU-seconds while executing,
    bandwidth at cloudlet IO edges.  (RAM/storage are billed at VM creation
    inside the provisioner — a placement decision, not an interval integral.)
    """

    name = "market"

    def post(self, scn: Scenario, st: SimState, ev: StepEvent, aux):
        cls = scn.cloudlets
        # Bill against the dispatched assignment (== cls.vm for fixed rows);
        # unassigned rows are never active and never hit an IO edge.
        dc_of_cl = st.vm_dc[jnp.clip(st.cl_vm, 0, scn.vms.n_vms - 1)]
        run_cost = jnp.where(
            ev.active, ev.dt * scn.market.cost_per_cpu_sec[dc_of_cl], 0.0
        )
        io_mb = jnp.where(ev.newly_started, cls.input_mb, 0.0) + jnp.where(
            ev.newly_finished, cls.output_mb, 0.0
        )
        io_cost = io_mb * scn.market.cost_per_bw_mb[dc_of_cl]
        dc_seg = jnp.clip(dc_of_cl, 0, scn.hosts.n_dc - 1)
        st = st.replace(
            cpu_cost=st.cpu_cost.at[dc_seg].add(run_cost),
            bw_cost=st.bw_cost.at[dc_seg].add(io_cost),
        )
        return st, aux


@pytree_dataclass
class EnergyInstrument(Instrument):
    """Integrate P(t)·dt per DC under the linear power model (energy.py).

    No-op when ``Scenario.power`` is None — energy stays exactly zero.
    """

    name = "energy"

    def post(self, scn: Scenario, st: SimState, ev: StepEvent, aux):
        if scn.power is None:
            return st, aux
        from repro.core import energy as energy_mod

        watts = energy_mod.power_draw(scn, st, vm_mips=ev.vm_mips)
        return st.replace(energy_j=st.energy_j + watts * ev.dt), aux


@pytree_dataclass
class AutoscaleInstrument(Instrument):
    """Threshold-based horizontal scaling over the pre-declared VM pool.

    Every ``sensor_interval`` (a ``K_SCALE`` clock stop, so the loop never
    jumps across an evaluation) the autoscaler reads per-DC *demand*
    utilization (``provision.demand_load`` — queued work counts fully, so
    the signal is run-queue pressure, not allocation):

    * **scale up** — demand above ``scale_up_thresh`` at two consecutive
      ticks (i.e. sustained for a full sensor interval) activates the
      lowest-index inactive pool VM of that DC; the provisioner places it in
      the same step and it boots with the usual fixed creation latency.
    * **scale down** — demand below ``scale_down_thresh`` releases one
      idle (booted, no outstanding work) pool VM of that DC.  Release is
      terminal: inactive -> activating -> active -> released (DESIGN.md §7).

    All decisions are traced data (``Policy.autoscale`` gates everything), so
    one compilation serves autoscaled and static runs alike and campaigns
    vmap over arrival-rate x threshold grids.  The tick count depends on the
    traced horizon, so scenarios attaching this instrument must set
    ``Scenario.max_steps`` explicitly, like the federation builders do.
    """

    name = "autoscale"
    bound_kind = K_SCALE

    def init(self, scn: Scenario):
        D = scn.hosts.n_dc
        return (
            jnp.asarray(0.0, jnp.float32),   # last evaluation time
            jnp.zeros((D,), bool),           # was over-threshold at last tick
            jnp.asarray(0, jnp.int32),       # activations
            jnp.asarray(0, jnp.int32),       # releases
        )

    def pre(self, scn: Scenario, st: SimState, aux):
        last_t, over_prev, n_up, n_down = aux
        pol, vms = scn.policy, scn.vms
        V, D = vms.n_vms, scn.hosts.n_dc
        due = pol.autoscale & (st.t >= last_t + pol.sensor_interval)
        util = provision.demand_load(scn, st)                           # [D]
        over = util > pol.scale_up_thresh
        under = util < pol.scale_down_thresh
        rows = jnp.arange(V)

        # scale up: sustained pressure activates one inactive pool row per DC
        want_up = due & over & over_prev                                # [D]
        cand_up = (
            vms.pool & vms.exists & ~st.pool_active & ~st.vm_placed
            & ~st.vm_failed & want_up[vms.dc]
        )
        first_up = jnp.full((D,), V).at[vms.dc].min(
            jnp.where(cand_up, rows, V)
        )
        act = cand_up & (rows == first_up[vms.dc])

        # scale down: one idle booted pool row per under-pressure DC
        dc_now = jnp.clip(st.vm_dc, 0, D - 1)
        seg = jnp.where(scn.cloudlets.exists & (st.cl_vm >= 0), st.cl_vm, V)
        busy = segments.segment_sum(
            (~policies.cloudlet_finished(st)).astype(jnp.float32), seg, V
        ) > 0
        cand_down = (
            vms.pool & st.pool_active & st.vm_placed & ~st.vm_released
            & (st.vm_avail_t <= st.t) & ~busy & (due & under)[dc_now]
        )
        first_down = jnp.full((D,), V).at[dc_now].min(
            jnp.where(cand_down, rows, V)
        )
        rel = cand_down & (rows == first_down[dc_now])

        st = provision.release_pool_vms(scn, st, rel)
        st = st.replace(pool_active=st.pool_active | act)
        aux = (
            jnp.where(due, st.t, last_t),
            jnp.where(due, over, over_prev),
            n_up + jnp.sum(act.astype(jnp.int32)),
            n_down + jnp.sum(rel.astype(jnp.int32)),
        )
        return st, aux

    def bound(self, scn: Scenario, st: SimState, aux) -> Array:
        pol = scn.policy
        return jnp.where(pol.autoscale, aux[0] + pol.sensor_interval, INF)

    def finalize(self, scn: Scenario, st: SimState, aux) -> dict:
        return {"n_scale_up": aux[2], "n_scale_down": aux[3]}


@pytree_dataclass
class MigrationInstrument(Instrument):
    """Runtime (live) VM migration across federated datacenters — the
    CloudCoordinator policy layer the paper's abstract promises beyond the
    creation-time Table-1 rule (DESIGN.md §8).

    At every federation sensor tick (a ``K_TICK`` clock stop, so the loop
    never jumps across an evaluation) the coordinator reads per-DC *demand*
    utilization (``provision.demand_load``) and commits at most ONE move:

    * **load balancing** (loaded -> spare) — the most-loaded DC above
      ``migrate_balance_thresh`` sheds its VM with the most outstanding work
      to the least-loaded feasible peer, but only when the move strictly
      shrinks the pair's utilization spread — the improvement rule that
      makes ping-pong impossible.
    * **energy consolidation** (spare -> loaded) — the least-loaded DC below
      ``migrate_consolidate_thresh`` drains its VM with the *least*
      outstanding work (idle images first) toward the busiest strictly-busier
      feasible peer, emptying hosts for idle power-gating (energy.py).

    Balance outranks consolidation within a tick.  The commit itself is
    ``provision.live_migrate``: source slot released, destination slot
    occupied in the same event, transfer billed on the inter-DC bandwidth
    meter, and the VM unavailable for ``migration_fixed_s + image/bw`` via
    the existing ``vm_avail_t`` / ``K_MIGRATION`` machinery — in-flight
    cloudlets keep their accrued progress.

    Everything is traced (``Policy.federation & Policy.live_migration`` gate
    it all), so a migration run and its static control share one compiled
    program and campaigns vmap over threshold grids.  Attach the instrument
    statically; sweep the flags/thresholds as data.  The tick count depends
    on the traced horizon, so scenarios attaching this must set
    ``Scenario.max_steps`` explicitly, like the federation builders do.
    """

    name = "migration"
    bound_kind = K_TICK

    def init(self, scn: Scenario):
        return (
            jnp.asarray(0.0, jnp.float32),   # last evaluation time
            jnp.asarray(0, jnp.int32),       # balance moves committed
            jnp.asarray(0, jnp.int32),       # consolidation moves committed
        )

    def pre(self, scn: Scenario, st: SimState, aux):
        last_t, n_bal, n_con = aux
        pol, vms = scn.policy, scn.vms
        V, D = vms.n_vms, scn.hosts.n_dc
        enabled = pol.federation & pol.live_migration
        due = enabled & (st.t >= last_t + pol.sensor_interval)

        st = _clear_arrived_moves(st)

        util = provision.demand_load(scn, st)                      # [D]
        cap = jnp.maximum(provision.dc_capacity_mips(scn), 1e-9)   # [D]
        outstanding = policies.vm_outstanding_mi(scn, st)          # [V]
        demand = policies.vm_demand_mips(scn, st)                  # [V]
        movable = (
            vms.exists & st.vm_placed & ~st.vm_failed & ~st.vm_released
            & (st.vm_avail_t <= st.t)
        )
        dc_of = jnp.clip(st.vm_dc, 0, D - 1)
        has_movable = jnp.zeros((D,), jnp.float32).at[dc_of].add(
            movable.astype(jnp.float32)) > 0
        dcs = jnp.arange(D)

        # --- load balancing: loaded source sheds its busiest VM ---
        src_ok_b = has_movable & (util > pol.migrate_balance_thresh)
        src_b = jnp.argmax(jnp.where(src_ok_b, util, -jnp.inf))
        v_b = jnp.argmax(jnp.where(
            movable & (dc_of == src_b), outstanding, -jnp.inf))
        dst_ok_b = (
            jnp.any(provision.slot_feasible(scn, st, v_b), axis=1)
            & (dcs != src_b)
        )
        dst_b = jnp.argmin(jnp.where(dst_ok_b, util, jnp.inf))
        # improvement rule: the move must strictly shrink the pair's spread
        spread_after = jnp.maximum(
            util[src_b] - demand[v_b] / cap[src_b],
            util[dst_b] + demand[v_b] / cap[dst_b],
        )
        bal_ok = (
            due & jnp.any(src_ok_b) & jnp.any(dst_ok_b)
            & (spread_after < util[src_b] - 1e-6)
        )

        # --- consolidation: idle source drains toward a busier peer ---
        src_ok_c = has_movable & (util < pol.migrate_consolidate_thresh)
        src_c = jnp.argmin(jnp.where(src_ok_c, util, jnp.inf))
        v_c = jnp.argmin(jnp.where(
            movable & (dc_of == src_c), outstanding, jnp.inf))
        dst_ok_c = (
            jnp.any(provision.slot_feasible(scn, st, v_c), axis=1)
            & (dcs != src_c)
            & (util > util[src_c] + 1e-6)   # strictly busier: terminates
        )
        dst_c = jnp.argmax(jnp.where(dst_ok_c, util, -jnp.inf))
        con_ok = due & jnp.any(src_ok_c) & jnp.any(dst_ok_c) & ~bal_ok

        v = jnp.where(bal_ok, v_b, v_c)
        dst = jnp.where(bal_ok, dst_b, dst_c)
        st, moved = provision.live_migrate(scn, st, v, dst, bal_ok | con_ok)
        aux = (
            jnp.where(due, st.t, last_t),
            n_bal + (moved & bal_ok).astype(jnp.int32),
            n_con + (moved & con_ok).astype(jnp.int32),
        )
        return st, aux

    def bound(self, scn: Scenario, st: SimState, aux) -> Array:
        pol = scn.policy
        return jnp.where(
            pol.federation & pol.live_migration,
            aux[0] + pol.sensor_interval, INF,
        )

    def finalize(self, scn: Scenario, st: SimState, aux) -> dict:
        return {"n_balance": aux[1], "n_consolidate": aux[2]}


def _clear_arrived_moves(st: SimState) -> SimState:
    """Reset the pending-move marker for transfers that have landed — shared
    bookkeeping for every instrument that commits ``provision.live_migrate``
    moves (MigrationInstrument, ReliabilityInstrument)."""
    return st.replace(vm_mig_src=jnp.where(
        (st.vm_mig_src >= 0) & (st.vm_avail_t <= st.t),
        -1, st.vm_mig_src))


def _evac_candidate(scn: Scenario, st: SimState):
    """(v, dst_dc, safe, ok) — the next proactive evacuation the coordinator
    would commit right now: the usable VM with the most outstanding work on
    a *doomed* host (scheduled to fail within ``evac_lead_s``), bound for
    the least-loaded federation peer with a safe free slot (``safe`` is the
    ``[D, H]`` landing mask).  Shared by ``ReliabilityInstrument.pre`` (the
    commit) and ``.bound`` (the clock stop that keeps the drain going), so
    they can never disagree.
    """
    pol, vms, hosts = scn.policy, scn.vms, scn.hosts
    D = hosts.n_dc
    nf = scn.outages.next_fail_after(st.t)                      # [D,H]
    doomed = hosts.exists & st.host_up & (nf <= st.t + pol.evac_lead_s)
    d = jnp.clip(st.vm_dc, 0, D - 1)
    h = jnp.clip(st.vm_host, 0, hosts.n_hosts - 1)
    cand = (
        vms.exists & st.vm_placed & ~st.vm_released & ~st.vm_failed
        & (st.vm_avail_t <= st.t) & doomed[d, h]
    )
    outstanding = policies.vm_outstanding_mi(scn, st)
    v = jnp.argmax(jnp.where(cand, outstanding, -jnp.inf))
    # destination: a peer DC with a free slot on a host that is neither down
    # nor itself about to fail — evacuating into the blast radius is not a
    # rescue; the commit passes ``safe`` to live_migrate so the landing
    # host choice honours it too
    safe = provision.slot_feasible(scn, st, v) & ~doomed
    dst_ok = jnp.any(safe, axis=1) & (jnp.arange(D) != jnp.clip(
        st.vm_dc[v], 0, D - 1))
    util = provision.demand_load(scn, st)
    dst = jnp.argmin(jnp.where(dst_ok, util, jnp.inf))
    enabled = pol.federation & pol.evacuation
    ok = enabled & jnp.any(cand) & jnp.any(dst_ok)
    return v, dst, safe, ok


@pytree_dataclass
class ReliabilityInstrument(Instrument):
    """Proactive evacuation ahead of scheduled host failures (DESIGN.md §9).

    The failure *semantics* — K_FAILURE/K_REPAIR edges, eviction, checkpoint
    rollback, downtime accrual — live in the engine (``provision.apply_outages``
    + event_step), because revocation changes what happened, not what was
    observed.  The failure *policy* rides the PR-1 hooks like the autoscaler
    and the migration coordinator:

    * ``bound()`` contributes an evacuation *alarm* — ``Policy.evac_lead_s``
      before each host's next scheduled failure — as a clock stop, and while
      a usable VM still sits on a doomed host with a feasible federation
      peer, keeps the clock stopped (zero-length events) so the drain
      commits one move per event.
    * ``pre()`` commits that move through ``provision.live_migrate`` — the
      §8 stop-and-copy machinery: progress preserved, source slot freed,
      destination slot taken in the same event, transfer window through
      ``vm_avail_t``, image billed on the inter-DC meter — and counts it in
      ``SimState.n_evacuations``.

    VMs with no feasible peer are left to the failure edge: eviction +
    rollback + re-queue through the creation path.  Everything is gated by
    ``Policy.federation & Policy.evacuation`` (both traced), so an
    evacuating run and its fatalist control are one compiled program and
    campaigns vmap MTBF x ckpt-interval x policy grids (tests/
    test_reliability.py).  Statically a no-op when ``Scenario.outages`` is
    None.  Alarm counts depend on the traced schedule, so scenarios
    attaching this set ``Scenario.max_steps`` explicitly, like the
    federation builders do.
    """

    name = "reliability"

    def init(self, scn: Scenario):
        return ()

    def pre(self, scn: Scenario, st: SimState, aux):
        if scn.outages is None:
            return st, aux
        st = _clear_arrived_moves(st)
        v, dst, safe, ok = _evac_candidate(scn, st)
        st, moved = provision.live_migrate(scn, st, v, dst, ok, host_ok=safe)
        return st.replace(
            n_evacuations=st.n_evacuations + moved.astype(jnp.int32)
        ), aux

    def bound(self, scn: Scenario, st: SimState, aux) -> Array:
        if scn.outages is None:
            return INF
        pol, hosts = scn.policy, scn.hosts
        nf = jnp.where(
            hosts.exists & st.host_up,
            scn.outages.next_fail_after(st.t), INF)
        alarm = jnp.min(jnp.where(nf < INF / 2, nf - pol.evac_lead_s, INF))
        future = jnp.where(alarm > st.t, alarm, INF)
        # more to drain right now -> stop the clock (dt = 0); each event
        # moves one VM, so the stop clears in at most |residents| events
        _, _, _, ok_now = _evac_candidate(scn, st)
        return jnp.where(
            pol.federation & pol.evacuation,
            jnp.where(ok_now, st.t, future), INF)


@pytree_dataclass
class TraceInstrument(Instrument):
    """Per-cloudlet progress fractions at ``sample_ts`` — a pure observer.

    Rates are piecewise-constant, so mid-interval progress interpolates
    *exactly*: rem(s) = rem(t0) − rate·(s − t0) for s in [t0, t1].  No clock
    stop is added, hence a traced run's event stream — and every ``SimResult``
    field, including cost and energy — is bit-identical to the untraced run
    (DESIGN.md §2; tests/test_trace_equivalence.py).  Rows of the output align
    with ``sample_ts`` as given.
    """

    name = "trace"

    sample_ts: Array   # [S] f32 absolute sample times

    def init(self, scn: Scenario):
        S = self.sample_ts.shape[0]
        C = scn.cloudlets.n_cloudlets
        return (
            jnp.zeros((S, C), jnp.float32),   # progress fractions
            jnp.zeros((S,), bool),            # recorded mask
        )

    def post(self, scn: Scenario, st: SimState, ev: StepEvent, aux):
        prog, recorded = aux
        ts = self.sample_ts
        length = scn.cloudlets.length_mi
        dt_s = jnp.clip(ts - ev.t0, 0.0, ev.dt)                       # [S]
        depleted = ev.rate[None, :] * dt_s[:, None]                    # [S, C]
        rem_s = jnp.where(
            ev.active[None, :],
            jnp.maximum(ev.rem_before[None, :] - depleted, 0.0),
            ev.rem_before[None, :],
        )
        frac = 1.0 - rem_s / jnp.maximum(length, 1e-9)[None, :]
        hit = ~recorded & (ts <= ev.t1)
        prog = jnp.where(hit[:, None], frac, prog)
        return st, (prog, recorded | hit)

    def finalize(self, scn: Scenario, st: SimState, aux) -> dict:
        prog, recorded = aux
        # Samples past the last event see the frozen final state exactly.
        final = 1.0 - st.rem_mi / jnp.maximum(scn.cloudlets.length_mi, 1e-9)
        return {"progress": jnp.where(recorded[:, None], prog, final[None, :])}


@pytree_dataclass
class UtilizationTimelineInstrument(Instrument):
    """Per-DC utilization sampled at ``sample_ts`` — the Figure 9/10-style
    observable the pre-instrument engine could not produce without a fork.
    """

    name = "utilization"

    sample_ts: Array   # [S] f32

    def init(self, scn: Scenario):
        S = self.sample_ts.shape[0]
        return (
            jnp.zeros((S, scn.hosts.n_dc), jnp.float32),
            jnp.zeros((S,), bool),
        )

    def post(self, scn: Scenario, st: SimState, ev: StepEvent, aux):
        util_tl, recorded = aux
        from repro.core import energy as energy_mod

        util = energy_mod.dc_utilization(scn, st, vm_mips=ev.vm_mips)  # [D]
        hit = ~recorded & (self.sample_ts <= ev.t1)
        util_tl = jnp.where(hit[:, None], util[None, :], util_tl)
        return st, (util_tl, recorded | hit)

    def finalize(self, scn: Scenario, st: SimState, aux) -> dict:
        util_tl, recorded = aux
        from repro.core import energy as energy_mod

        final = energy_mod.dc_utilization(scn, st)
        return {
            "utilization": jnp.where(recorded[:, None], util_tl, final[None, :])
        }


def default_instruments() -> tuple[Instrument, ...]:
    """The always-on observables — the semantics ``simulate`` ships with."""
    return (SensorInstrument(), MarketInstrument(), EnergyInstrument())


@pytree_dataclass(static=("advance",))
class StepContext:
    """Loop-invariant context resolved once per driver.

    ``advance`` is static (it keys the jit cache: jnp vs Pallas); the
    instrument tuple is traced data, so campaigns may vmap over it.  (Ready
    times are *state* now — ``SimState.cl_ready_t`` — because service-routed
    rows learn theirs only at dispatch.)
    """

    instruments: tuple             # tuple[Instrument, ...]
    advance: Callable = None


def instruments_for(
    scn: Scenario, extra_instruments: tuple = ()
) -> tuple[Instrument, ...]:
    """The full instrument tuple a driver threads through the loop.

    Order — defaults, then ``Scenario.instruments``, then driver extras — is
    the accrual order inside each step.  The batch-major step rebuilds this
    inside its vmapped phase closures, so per-row instrument leaves (a
    campaign sweeping instrument fields) map correctly while driver extras
    stay captured unbatched.
    """
    return default_instruments() + tuple(scn.instruments) + tuple(
        extra_instruments
    )


def init_aux(scn: Scenario, extra_instruments: tuple = ()) -> tuple:
    """Initial instrument aux states (vmapped per row by the batch drivers)."""
    return tuple(
        ins.init(scn) for ins in instruments_for(scn, extra_instruments)
    )


def make_context(
    scn: Scenario, extra_instruments: tuple = ()
) -> tuple[StepContext, tuple]:
    """Build the step context + initial instrument aux states for a driver.

    Instrument order — defaults, then ``Scenario.instruments``, then driver
    extras — is the accrual order inside each step.
    """
    instruments = instruments_for(scn, extra_instruments)
    names = [ins.name for ins in instruments]
    dupes = {n for n in names if names.count(n) > 1}
    if dupes:
        raise ValueError(
            f"duplicate instrument name(s) {sorted(dupes)}: outputs are keyed "
            "by name — give each instance a distinct `name` class attr"
        )
    ctx = StepContext(
        instruments=instruments,
        advance=resolve_advance(scn),
    )
    aux = tuple(ins.init(scn) for ins in instruments)
    return ctx, aux


# ---------------------------------------------------------------------------
# the event-step phases (DESIGN.md §10)
#
# ``event_step`` is decomposed into phase functions so the batch-major step
# can vmap each phase over the scenario axis while keeping the expensive
# phases (the provisioning scan, broker dispatch) behind *scalar*
# ``lax.cond``s on batch-global predicates.  Under vmap a batched-predicate
# cond degrades to a select (both branches execute); a scalar predicate on
# the whole batch genuinely skips the phase — the structural advantage the
# batch-major path has over vmap-of-``simulate``.  Each skipped phase is an
# exact identity whenever its predicate is False (every write inside is
# gated by the same ``due`` mask the predicate reduces), so skipping
# preserves bitwise identity.
# ---------------------------------------------------------------------------


def _provision_needed(scn: Scenario, st: SimState) -> Array:
    """Any due, unplaced, unfailed VM request (the exact ``due`` mask of
    ``provision.provision_due_vms``) — includes failure-evicted rows, which
    retry at every event."""
    vms = scn.vms
    due = (
        vms.exists & ~st.vm_placed & ~st.vm_failed
        & (vms.request_t <= st.t) & (~vms.pool | st.pool_active)
    )
    return jnp.any(due)


def _dispatch_needed(scn: Scenario, st: SimState) -> Array:
    """Any submitted service-routed cloudlet still unbound (the exact ``due``
    mask of ``provision.dispatch_cloudlets``)."""
    cls = scn.cloudlets
    return jnp.any(cls.exists & (st.cl_vm < 0) & (cls.submit_t <= st.t))


def _phase_prologue(
    scn: Scenario, st: SimState, aux: tuple, instruments: tuple
) -> tuple[SimState, tuple]:
    """Outage edges, instrument ``pre`` hooks, release of drained VMs."""
    # --- host failure/repair edges (Scenario.outages), before anything may
    #     observe or use the dead hosts: evict residents, roll back work ---
    st = provision.apply_outages(scn, st)

    # --- close arrived/cancelled transfers so their link slots are free
    #     before this event's migration commits and stage-in opens ---
    if scn.topology is not None:
        st = provision.settle_transfers(scn, st)

    # --- instrument pre hooks (Sensor tick refreshes sensed_load) ---
    aux = list(aux)
    for i, ins in enumerate(instruments):
        st, aux[i] = ins.pre(scn, st, aux[i])

    # --- VM lifecycle: destroy drained VMs (placement happens next phase) ---
    st = provision.release_done_vms(scn, st)
    return st, tuple(aux)


def _cand_kinds(scn: Scenario, instruments: tuple) -> Array:
    """Static event-kind classification aligned with ``_phase_bound``'s
    candidate times (same per scenario row — shapes and instrument tuples
    are static across a campaign)."""
    cand_k = [K_READY, K_READY, K_VM_REQUEST, K_MIGRATION, K_SERVING]
    if scn.topology is not None:
        cand_k.append(K_STAGE)
    if scn.outages is not None:
        cand_k += [K_FAILURE, K_REPAIR]
    cand_k += [ins.bound_kind for ins in instruments]
    cand_k.append(K_HORIZON)
    return jnp.asarray(cand_k, jnp.int32)


def _phase_bound(
    scn: Scenario, st: SimState, aux: tuple, instruments: tuple
) -> tuple[Array, Array, Array, Array, Array]:
    """Policy sweep + next-event bound: (rate, vm_mips, active, bound_dt,
    cand_ts)."""
    pol, cls, vms = scn.policy, scn.cloudlets, scn.vms

    # --- the updateVMsProcessing sweep: rates for every task unit ---
    rate, vm_mips = policies.cloudlet_rates(scn, st)
    active = rate > 0

    # --- next event bound from non-completion sources ---
    unready = cls.exists & (st.cl_ready_t > st.t)
    undispatched = cls.exists & (st.cl_vm < 0) & (cls.submit_t > st.t)
    # evicted rows' request_t is in the past — they retry at *every* event
    # (and wake on K_REPAIR / completions), so they contribute no bound
    unplaced = (
        vms.exists & ~st.vm_placed & ~st.vm_failed & ~st.vm_evicted
        & (~vms.pool | st.pool_active)
    )
    migrating = vms.exists & st.vm_placed & (st.vm_avail_t > st.t)
    cand_t = [
        _min_where(st.cl_ready_t, unready),
        _min_where(cls.submit_t, undispatched),
        _min_where(vms.request_t, unplaced),
        _min_where(st.vm_avail_t, migrating),
        # decoding requests stop the clock at KV-block boundaries so cache
        # growth — and preemption-on-exhaustion — lands on exact edges
        kvserve.serving_bound(scn, st, rate),
    ]
    if scn.topology is not None:
        # a bound network stage-in submitted in the future must wake the
        # loop at its submit time so the transfer phase can open it
        staging = (
            cls.exists & (cls.input_dc >= 0) & (st.cl_vm >= 0)
            & (st.cl_xfer_dst < 0) & (st.cl_ready_t >= INF / 2)
            & (cls.submit_t > st.t)
        )
        cand_t.append(_min_where(cls.submit_t, staging))
    if scn.outages is not None:
        ex = scn.hosts.exists
        cand_t.append(jnp.min(jnp.where(
            ex, scn.outages.next_fail_after(st.t), INF)))
        cand_t.append(jnp.min(jnp.where(
            ex, scn.outages.next_repair_after(st.t), INF)))
    for i, ins in enumerate(instruments):
        cand_t.append(ins.bound(scn, st, aux[i]))
    cand_t.append(pol.horizon)
    cand_ts = jnp.stack(cand_t)
    bound_t = jnp.min(cand_ts)
    bound_dt = jnp.maximum(bound_t - st.t, 0.0)
    return rate, vm_mips, active, bound_dt, cand_ts


def _phase_commit(
    scn: Scenario,
    st: SimState,
    aux: tuple,
    instruments: tuple,
    rate: Array,
    vm_mips: Array,
    active: Array,
    cand_ts: Array,
    dt: Array,
    new_rem: Array,
) -> tuple[tuple[SimState, tuple], StepEvent]:
    """State update after the advance sweep + instrument ``post`` hooks."""
    cls = scn.cloudlets
    t_next = st.t + dt

    newly_started = active & ~st.started
    newly_fin = active & (new_rem <= _eps_mi(cls.length_mi))
    new_rem = jnp.where(newly_fin, 0.0, new_rem)

    kind = jnp.where(
        jnp.any(newly_fin),
        K_COMPLETION,
        _cand_kinds(scn, instruments)[jnp.argmin(cand_ts)],
    )
    ev = StepEvent(
        t0=st.t,
        t1=t_next,
        dt=dt,
        kind=kind,
        rate=rate,
        active=active,
        rem_before=st.rem_mi,
        newly_started=newly_started,
        newly_finished=newly_fin,
        vm_mips=vm_mips,
    )

    st = st.replace(
        t=t_next,
        step=st.step + 1,
        rem_mi=new_rem,
        started=st.started | newly_started,
        start_t=jnp.where(newly_started, st.t, st.start_t),
        finish_t=jnp.where(newly_fin, t_next, st.finish_t),
        cpu_time=st.cpu_time + jnp.where(active, dt, 0.0),
    )
    if scn.outages is not None:
        # downtime integral: a VM is down while evicted and not yet usable
        # again (intervals never span a recovery edge: vm_avail_t is a
        # K_MIGRATION clock stop and apply_outages clears on arrival)
        vm_down = st.vm_evicted & ~(st.vm_placed & (st.vm_avail_t <= ev.t0))
        st = st.replace(
            vm_downtime=st.vm_downtime + jnp.where(vm_down, dt, 0.0)
        )

    # --- instrument post hooks (market, energy, observers) ---
    aux = list(aux)
    for i, ins in enumerate(instruments):
        st, aux[i] = ins.post(scn, st, ev, aux[i])

    return (st, tuple(aux)), ev


def event_step(
    scn: Scenario, carry: tuple[SimState, tuple], ctx: StepContext
) -> tuple[tuple[SimState, tuple], StepEvent]:
    """Advance the world by one event batch.  THE event-loop body.

    ``carry`` is ``(SimState, instrument aux tuple)``; returns the stepped
    carry plus the emitted ``StepEvent``.  Pure, jittable, vmappable; every
    driver — while_loop or scan — wraps exactly this function (the
    batch-major drivers wrap ``batch_event_step``, which composes the same
    phases over a ``[B, ...]`` scenario axis).

    The provisioning scan and broker dispatch sit behind scalar
    ``lax.cond``s: most events have no due VM request and no unbound
    cloudlet, and both phases are exact identities then, so skipping them is
    free throughput at bitwise-identical results.  (Under vmap the conds
    lower to selects — both branches run — which is exactly the pre-refactor
    cost; the batch-major path keeps the predicates batch-global and scalar,
    so *it* genuinely skips.)
    """
    st, aux = carry
    instruments = ctx.instruments

    st, aux = _phase_prologue(scn, st, aux, instruments)

    # --- VM placement + broker dispatch, skipped when nothing is due ---
    with jax.named_scope(SCOPE_PROVISION):
        st = jax.lax.cond(
            _provision_needed(scn, st),
            lambda s: provision.provision_due_vms(scn, s)[0],
            lambda s: s,
            st,
        )
    with jax.named_scope(SCOPE_DISPATCH):
        st = jax.lax.cond(
            _dispatch_needed(scn, st),
            lambda s: provision.dispatch_cloudlets(scn, s),
            lambda s: s,
            st,
        )

    # --- contention-aware transfer phase: open due stage-ins, re-time
    #     in-flight transfers on occupancy-changed links (DESIGN.md §13) ---
    if scn.topology is not None:
        with jax.named_scope(SCOPE_TRANSFER):
            st = jax.lax.cond(
                provision.transfer_needed(scn, st),
                lambda s: provision.transfer_phase(scn, s),
                lambda s: s,
                st,
            )

    # --- KV-block ledger sweep: release / growth / eviction / admission
    #     for LLM-serving rows; skipped (and bitwise inert) without any ---
    with jax.named_scope(SCOPE_SERVING):
        st = jax.lax.cond(
            kvserve.serving_needed(scn, st),
            lambda s: kvserve.serving_phase(scn, s),
            lambda s: s,
            st,
        )

    rate, vm_mips, active, bound_dt, cand_ts = _phase_bound(
        scn, st, aux, instruments
    )

    # --- fused advance: completion min-reduce + work depletion ---
    dt, new_rem = ctx.advance(st.rem_mi, rate, active, bound_dt)

    return _phase_commit(
        scn, st, aux, instruments, rate, vm_mips, active, cand_ts, dt, new_rem
    )


# ---------------------------------------------------------------------------
# batch-major step: the campaign dimension inside the program (DESIGN.md §10)
# ---------------------------------------------------------------------------


def batch_live(scn_b: Scenario, st_b: SimState, max_steps: int) -> Array:
    """[B] per-row loop-continuation mask — ``step_cond`` vmapped over the
    scenario axis.  The batch drivers' loop condition is ``any(live)``."""
    return jax.vmap(lambda scn, st: step_cond(scn, st, max_steps))(
        scn_b, st_b
    )


def _freeze(live: Array, new, old):
    """Per-leaf row select: live rows take the stepped value, finished rows
    stay bitwise frozen at their final state (early-exit masking)."""
    return jax.tree.map(
        lambda a, b: jnp.where(
            live.reshape(live.shape + (1,) * (a.ndim - 1)), a, b
        ),
        new,
        old,
    )


def batch_event_step(
    scn_b: Scenario,
    carry: tuple[SimState, tuple],
    ctx: StepContext,
    extra_instruments: tuple,
    max_steps: int,
) -> tuple[tuple[SimState, tuple], StepEvent, Array]:
    """Advance a ``[B, ...]`` batch of scenarios by one event batch each.

    The same phases as ``event_step``, vmapped over the scenario axis, with
    three batch-major specifics:

    * **phase skipping** — the provisioning scan and broker dispatch run
      under *scalar* ``lax.cond``s on batch-global predicates
      (``any(needed & live)``), so an event where no live row has work for
      the phase skips it for the whole batch — the cost structure
      vmap-of-``simulate`` cannot express (its conds lower to selects).
    * **batch-grid advance** — the advance sweep is called *outside* the
      vmapped phases on the full ``[B, C]`` block, so ``sweep_impl="pallas"``
      lands on the fused batch-grid kernel (one grid step per scenario row).
    * **early-exit masking** — rows whose ``step_cond`` is already False are
      frozen: every state/aux write is row-gated by ``live``, so a finished
      scenario's trajectory is bitwise that of its solo run no matter how
      long the batch keeps looping.

    Instruments are rebuilt per row inside the vmapped closures
    (``instruments_for``), so batched ``Scenario.instruments`` leaves map
    per-row while driver ``extra_instruments`` stay captured unbatched.
    Returns ``(carry', event batch, live)`` — dead rows' event fields are
    garbage and must be masked with ``live`` by observers.
    """
    st_b, aux_b = carry
    extras = tuple(extra_instruments)
    live = batch_live(scn_b, st_b, max_steps)

    def prologue(scn, st, aux):
        return _phase_prologue(scn, st, aux, instruments_for(scn, extras))

    st1, aux1 = jax.vmap(prologue)(scn_b, st_b, aux_b)

    # --- VM placement + broker dispatch: batch-global skip predicates ---
    need_prov = jnp.any(jax.vmap(_provision_needed)(scn_b, st1) & live)
    with jax.named_scope(SCOPE_PROVISION):
        st2 = jax.lax.cond(
            need_prov,
            lambda s: jax.vmap(
                lambda scn, st: provision.provision_due_vms(scn, st)[0]
            )(scn_b, s),
            lambda s: s,
            st1,
        )
    need_disp = jnp.any(jax.vmap(_dispatch_needed)(scn_b, st2) & live)
    with jax.named_scope(SCOPE_DISPATCH):
        st3 = jax.lax.cond(
            need_disp,
            lambda s: jax.vmap(provision.dispatch_cloudlets)(scn_b, s),
            lambda s: s,
            st2,
        )

    if scn_b.topology is not None:
        need_xfer = jnp.any(
            jax.vmap(provision.transfer_needed)(scn_b, st3) & live
        )
        with jax.named_scope(SCOPE_TRANSFER):
            st3 = jax.lax.cond(
                need_xfer,
                lambda s: jax.vmap(provision.transfer_phase)(scn_b, s),
                lambda s: s,
                st3,
            )

    need_srv = jnp.any(jax.vmap(kvserve.serving_needed)(scn_b, st3) & live)
    with jax.named_scope(SCOPE_SERVING):
        st3 = jax.lax.cond(
            need_srv,
            lambda s: jax.vmap(kvserve.serving_phase)(scn_b, s),
            lambda s: s,
            st3,
        )

    def bound(scn, st, aux):
        return _phase_bound(scn, st, aux, instruments_for(scn, extras))

    rate, vm_mips, active, bound_dt, cand_ts = jax.vmap(bound)(
        scn_b, st3, aux1
    )

    # --- batch-grid advance on the whole [B, C] block (outside the vmap) ---
    dt, new_rem = ctx.advance(st3.rem_mi, rate, active, bound_dt)

    def commit(scn, st, aux, rate, vm_mips, active, cand_ts, dt, new_rem):
        return _phase_commit(
            scn, st, aux, instruments_for(scn, extras),
            rate, vm_mips, active, cand_ts, dt, new_rem,
        )

    (st4, aux2), ev = jax.vmap(commit)(
        scn_b, st3, aux1, rate, vm_mips, active, cand_ts, dt, new_rem
    )

    carry2 = _freeze(live, (st4, aux2), (st_b, aux_b))
    return carry2, ev, live


def finalize_outputs_for(
    scn: Scenario, st: SimState, aux: tuple, extra_instruments: tuple = ()
) -> dict:
    """Collect instrument outputs keyed by name, rebuilding the instrument
    tuple from the (per-row) scenario — the batch drivers' vmapped twin of
    ``finalize_outputs``."""
    out: dict = {}
    for ins, a in zip(instruments_for(scn, extra_instruments), aux):
        o = ins.finalize(scn, st, a)
        if o:
            out[ins.name] = o
    return out


def _masked_pct(x: Array, mask: Array, q: float) -> Array:
    """Nearest-rank percentile of ``x`` over ``mask`` rows; INF when empty."""
    xs = jnp.sort(jnp.where(mask, x, INF))
    k = jnp.sum(mask.astype(jnp.int32))
    idx = jnp.clip(
        jnp.ceil(q * k.astype(jnp.float32)).astype(jnp.int32) - 1,
        0, x.shape[0] - 1,
    )
    return jnp.where(k > 0, xs[idx], INF)


def finalize_result(scn: Scenario, st: SimState) -> SimResult:
    """Assemble the reported outcome from a final state (shared by drivers)."""
    cls = scn.cloudlets
    fin = policies.cloudlet_finished(st) & cls.exists
    tat = jnp.where(fin, st.finish_t - cls.submit_t, INF)
    n_fin = jnp.sum(fin.astype(jnp.int32))
    mean_tat = jnp.sum(jnp.where(fin, tat, 0.0)) / jnp.maximum(n_fin, 1)
    makespan = jnp.max(jnp.where(fin, st.finish_t, -INF), initial=-INF)
    total_cost = jnp.sum(
        st.cpu_cost + st.ram_cost + st.storage_cost + st.bw_cost
    )
    # serving tail latency (DESIGN.md §14): TTFT is queueing + KV admission
    # delay until the first decode step; TPOT the observed per-token pace
    # including any preemption stalls.  INF marks "no finished serving rows".
    sfin = fin & (cls.prompt_tokens > 0.0)
    ttft = jnp.where(sfin, st.start_t - cls.submit_t, INF)
    tpot = jnp.where(
        sfin,
        (st.finish_t - st.start_t) / jnp.maximum(cls.max_new_tokens, 1.0),
        INF,
    )
    return SimResult(
        finish_t=st.finish_t,
        start_t=st.start_t,
        cl_vm=st.cl_vm,
        turnaround=tat,
        makespan=makespan,
        mean_turnaround=mean_tat,
        n_finished=n_fin,
        n_events=st.step,
        n_migrations=jnp.sum(st.vm_migrations),
        vm_placed=st.vm_placed,
        vm_dc=st.vm_dc,
        vm_failed=st.vm_failed,
        cpu_cost=st.cpu_cost,
        ram_cost=st.ram_cost,
        storage_cost=st.storage_cost,
        bw_cost=st.bw_cost,
        energy_j=st.energy_j,
        total_cost=total_cost,
        end_t=st.t,
        sla_violations=jnp.sum(
            policies.sla_violation_mask(scn, st).astype(jnp.int32)),
        downtime=jnp.sum(st.vm_downtime),
        n_evacuations=st.n_evacuations,
        ttft_p50=_masked_pct(ttft, sfin, 0.50),
        ttft_p99=_masked_pct(ttft, sfin, 0.99),
        tpot_p50=_masked_pct(tpot, sfin, 0.50),
        tpot_p99=_masked_pct(tpot, sfin, 0.99),
    )


def finalize_outputs(
    scn: Scenario, st: SimState, ctx: StepContext, aux: tuple
) -> dict:
    """Collect instrument outputs keyed by instrument name."""
    out: dict = {}
    for ins, a in zip(ctx.instruments, aux):
        o = ins.finalize(scn, st, a)
        if o:
            out[ins.name] = o
    return out
