"""Streaming campaign reductions: fold chunk results, never hold ``[N, ...]``.

A 1e6-point policy study does not want a million stacked ``SimResult``
pytrees — it wants a handful of summary statistics (mean turnaround, tail
percentiles, violation counts, the single best policy row).  A
``CampaignReducer`` is an associative fold over campaign chunks with a
**fixed-shape carry**: ``run_campaign(batched, chunk_size=..., reduce=...)``
runs each chunk through the one compiled chunk program, folds the chunk's
``SimResult`` into the carry *inside the same jitted call* (so the chunk
result never even returns to Python), and hands back only the finalized
summary.  Working memory is bounded by one chunk plus the carry regardless
of campaign size (DESIGN.md §12).

Protocol
--------
``init(chunk_avals, res_avals)`` builds the carry from the chunk's abstract
shapes (``jax.eval_shape`` trees — no arrays materialized); ``fold(carry,
chunk, res, index, valid)`` consumes one ``[chunk]``-leading batch where
``index`` holds global row indices and ``valid`` masks the repeated-row
padding of the trailing chunk; ``finalize(carry)`` converts the carry to the
user-facing summary.  Reducers are frozen dataclasses, so they are hashable
and ride through ``jax.jit`` as static arguments — reuse ONE reducer
instance across calls or the jit cache forks per instance.

Determinism and chunk-size invariance
-------------------------------------
Integer folds (``SumReducer`` over counts, ``HistogramReducer`` bin counts,
``ArgBestReducer`` with first-lowest-index tie-breaking, ``ValuesReducer``
scatters) are associative and therefore **bitwise identical** for every
chunking of the same campaign.  Float sums (``MeanReducer``,
``SumReducer`` over f32 fields) regroup additions per chunk, so they agree
only to rounding; percentile estimates from ``HistogramReducer`` are exact
functions of the (bitwise-stable) bin counts, accurate to one bin width.
tests/test_reducers.py pins all of this against the materialized
``[N, ...]`` reference.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.entities import INF, Scenario, SimResult


def _metric_fn(metric):
    """Normalize a metric spec: a ``SimResult`` field name or a callable
    ``SimResult -> [B]`` array (one scalar per scenario row)."""
    if callable(metric):
        return metric
    if isinstance(metric, str):
        if metric not in {f.name for f in dataclasses.fields(SimResult)}:
            raise ValueError(
                f"unknown SimResult field {metric!r}; pass a callable for "
                "derived metrics"
            )
        return lambda res: getattr(res, metric)
    raise TypeError(f"metric must be a field name or callable, got {metric!r}")


def _metric_aval(metric, res_avals):
    """Abstract [B] value of ``metric`` (shape/dtype only, nothing runs)."""
    aval = jax.eval_shape(_metric_fn(metric), res_avals)
    if len(aval.shape) != 1:
        raise ValueError(
            f"reducer metrics must be one scalar per scenario row ([B]); "
            f"metric {metric!r} has shape {aval.shape} — reduce per-entity "
            "fields (e.g. turnaround [B, C]) to a row scalar in the callable"
        )
    return aval


@dataclasses.dataclass(frozen=True)
class CampaignReducer:
    """Base protocol — see the module docstring for the fold contract."""

    def init(self, chunk_avals: Scenario, res_avals: SimResult):
        raise NotImplementedError

    def fold(self, carry, chunk: Scenario, res: SimResult, index, valid):
        raise NotImplementedError

    def finalize(self, carry):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class SumReducer(CampaignReducer):
    """Total of a per-scenario metric — violation counts, downtime seconds.

    Integer metrics fold exactly (associative), so the streamed total is
    bitwise the materialized one for any chunk size.
    """

    metric: object

    def init(self, chunk_avals, res_avals):
        aval = _metric_aval(self.metric, res_avals)
        return jnp.zeros((), aval.dtype)

    def fold(self, carry, chunk, res, index, valid):
        v = _metric_fn(self.metric)(res)
        return carry + jnp.sum(jnp.where(valid, v, jnp.zeros((), v.dtype)))

    def finalize(self, carry):
        return carry


@dataclasses.dataclass(frozen=True)
class MeanReducer(CampaignReducer):
    """Streaming count/sum/sum-of-squares -> ``{n, mean, std}``.

    Float accumulation regroups per chunk, so expect rounding-level (not
    bitwise) agreement with the materialized reference.
    """

    metric: object

    def init(self, chunk_avals, res_avals):
        _metric_aval(self.metric, res_avals)  # validate rank early
        f32 = jnp.float32
        return (jnp.zeros((), f32), jnp.zeros((), f32), jnp.zeros((), f32))

    def fold(self, carry, chunk, res, index, valid):
        n, s, ss = carry
        v = _metric_fn(self.metric)(res).astype(jnp.float32)
        v = jnp.where(valid, v, 0.0)
        return (n + jnp.sum(valid.astype(jnp.float32)), s + jnp.sum(v),
                ss + jnp.sum(v * v))

    def finalize(self, carry):
        n, s, ss = carry
        mean = s / jnp.maximum(n, 1.0)
        var = jnp.maximum(ss / jnp.maximum(n, 1.0) - mean * mean, 0.0)
        return {"n": n, "mean": mean, "std": jnp.sqrt(var)}


@dataclasses.dataclass(frozen=True)
class HistogramReducer(CampaignReducer):
    """Fixed-shape histogram sketch -> bin counts + percentile estimates.

    ``bins`` i32 counters over ``[lo, hi]`` (values clipped into range, so
    the extreme bins double as under/overflow).  Bin counts are integer
    scatters — bitwise chunk-order invariant — and quantiles interpolate
    within the selected bin, so the estimate error is at most one bin width
    ``(hi - lo) / bins`` (the tolerance tests/test_reducers.py asserts).
    Fixed shape is the point: a P²-style sketch with data-dependent marker
    moves would still be fixed-shape, but the histogram keeps the fold a
    pure scatter-add the compiler can fuse into the chunk program.
    """

    metric: object
    lo: float
    hi: float
    bins: int = 64
    qs: tuple = (0.5, 0.9, 0.99)

    def __post_init__(self):
        if not (self.hi > self.lo):
            raise ValueError(f"empty histogram range [{self.lo}, {self.hi}]")
        if self.bins < 1:
            raise ValueError(f"bins must be >= 1, got {self.bins}")

    def init(self, chunk_avals, res_avals):
        _metric_aval(self.metric, res_avals)
        return jnp.zeros((self.bins,), jnp.int32)

    def fold(self, carry, chunk, res, index, valid):
        v = _metric_fn(self.metric)(res).astype(jnp.float32)
        width = (self.hi - self.lo) / self.bins
        idx = jnp.clip(((v - self.lo) / width).astype(jnp.int32),
                       0, self.bins - 1)
        # invalid rows scatter out of bounds and are dropped
        idx = jnp.where(valid, idx, self.bins)
        return carry.at[idx].add(1, mode="drop")

    def finalize(self, carry):
        counts = carry
        total = jnp.maximum(jnp.sum(counts), 1)
        cum = jnp.cumsum(counts)
        width = (self.hi - self.lo) / self.bins
        out = {"counts": counts,
               "edges": jnp.linspace(self.lo, self.hi, self.bins + 1)}
        for q in self.qs:
            target = q * total.astype(jnp.float32)
            bin_ = jnp.argmax(cum.astype(jnp.float32) >= target)
            # interpolate within the bin: how far into its count the
            # target falls
            below = jnp.where(bin_ > 0, cum[jnp.maximum(bin_ - 1, 0)], 0)
            in_bin = jnp.maximum(counts[bin_], 1).astype(jnp.float32)
            frac = jnp.clip((target - below) / in_bin, 0.0, 1.0)
            out[f"q{q:g}"] = self.lo + (bin_.astype(jnp.float32) + frac) * width
        return out


@dataclasses.dataclass(frozen=True)
class LatencyHistogramReducer(HistogramReducer):
    """Serving tail latency pooled across the whole campaign: per-*request*
    TTFT or TPOT values (``[B, C]``, not the usual per-row scalar) folded
    into one fixed-bin histogram (DESIGN.md §14).

    ``metric`` selects the latency: ``"ttft"`` is ``start_t - submit_t``
    (queueing + KV-admission delay until the first decode step),
    ``"tpot"`` is ``(finish_t - start_t) / max_new_tokens`` (observed
    per-token pace, preemption stalls included).  Only *finished serving*
    rows of *valid* scenario rows scatter; everything else drops out of
    bounds.  Counts are integer scatters — bitwise chunk-order invariant —
    and the inherited quantile finalize is exact to one bin width, so a
    million-scenario sweep gets fleet-wide p50/p99 tail latency without
    materializing a single per-row result.
    """

    def __post_init__(self):
        super().__post_init__()
        if self.metric not in ("ttft", "tpot"):
            raise ValueError(
                f"metric must be 'ttft' or 'tpot', got {self.metric!r}"
            )

    def init(self, chunk_avals, res_avals):
        # per-request values: no [B]-rank validation of the base class
        return jnp.zeros((self.bins,), jnp.int32)

    def fold(self, carry, chunk, res, index, valid):
        cls = chunk.cloudlets
        served = (
            cls.exists & (cls.prompt_tokens > 0.0)
            & (res.finish_t < INF / 2)
        )                                                        # [B, C]
        if self.metric == "ttft":
            v = res.start_t - cls.submit_t
        else:
            v = (res.finish_t - res.start_t) / jnp.maximum(
                cls.max_new_tokens, 1.0
            )
        width = (self.hi - self.lo) / self.bins
        idx = jnp.clip(((v - self.lo) / width).astype(jnp.int32),
                       0, self.bins - 1)
        keep = served & valid[:, None]
        idx = jnp.where(keep, idx, self.bins)    # drop out of bounds
        return carry.at[idx].add(1, mode="drop")


@dataclasses.dataclass(frozen=True)
class ArgBestReducer(CampaignReducer):
    """Best scenario row by a scalar metric, carrying its ``Policy`` row.

    Ties resolve to the lowest global row index (``argmin``/``argmax`` take
    the first occurrence inside a chunk; across chunks only a *strict*
    improvement replaces the incumbent), so the fold is bitwise chunk-size
    invariant — the property that lets a sharded million-point sweep name
    one winning policy deterministically.
    """

    metric: object
    mode: str = "min"

    def __post_init__(self):
        if self.mode not in ("min", "max"):
            raise ValueError(f"mode must be 'min' or 'max', got {self.mode!r}")

    def init(self, chunk_avals, res_avals):
        _metric_aval(self.metric, res_avals)
        row = jax.tree.map(
            lambda a: jnp.zeros(a.shape[1:], a.dtype), chunk_avals.policy
        )
        # carry best in sign space: always minimize sign * metric
        return (jnp.asarray(INF, jnp.float32), jnp.asarray(-1, jnp.int32),
                row)

    def fold(self, carry, chunk, res, index, valid):
        best, best_idx, best_row = carry
        sign = 1.0 if self.mode == "min" else -1.0
        v = _metric_fn(self.metric)(res).astype(jnp.float32)
        masked = jnp.where(valid, sign * v, INF)
        local = jnp.argmin(masked)           # first occurrence: lowest index
        cand = masked[local]
        improved = cand < best               # strict: incumbent wins ties
        best = jnp.where(improved, cand, best)
        best_idx = jnp.where(improved, index[local], best_idx)
        best_row = jax.tree.map(
            lambda leaf, old: jnp.where(improved, leaf[local], old),
            chunk.policy, best_row,
        )
        return (best, best_idx, best_row)

    def finalize(self, carry):
        best, best_idx, best_row = carry
        sign = 1.0 if self.mode == "min" else -1.0
        return {"value": sign * best, "index": best_idx, "policy": best_row}


@dataclasses.dataclass(frozen=True)
class ValuesReducer(CampaignReducer):
    """Scatter one scalar metric per scenario into a fixed ``[n_slots]``
    table — all of a campaign's scores without its ``[N, ...]`` results.

    The search driver's workhorse (core/search.py): ``n_slots`` stays the
    initial population size across successive-halving rungs, so every rung
    folds through the same compiled chunk program (simlint R5).  Scatters
    at distinct indices commute, so the table is bitwise chunk-size
    invariant.
    """

    metric: object
    n_slots: int

    def init(self, chunk_avals, res_avals):
        aval = _metric_aval(self.metric, res_avals)
        return (jnp.zeros((self.n_slots,), aval.dtype),
                jnp.zeros((self.n_slots,), bool))

    def fold(self, carry, chunk, res, index, valid):
        values, filled = carry
        v = _metric_fn(self.metric)(res)
        safe = jnp.where(valid, index, self.n_slots)  # OOB rows drop
        return (values.at[safe].set(v, mode="drop"),
                filled.at[safe].set(True, mode="drop"))

    def finalize(self, carry):
        values, filled = carry
        return {"values": values, "filled": filled}
