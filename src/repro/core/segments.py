"""Vectorized per-segment reductions in arrival (row) order.

CloudSim's space-shared queues are "first K entities whose cumulative core
demand fits" (paper Figure 4a/4c).  Tensorized, that is an *exclusive prefix
sum of demand within each segment, in row order*: entity i runs iff
``prefix(i) + demand(i) <= capacity(segment(i))``.

Implemented with one stable argsort + associative_scan, O(N log N), no
host<->device sync, fully vmappable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array


def segment_prefix_sum(values: Array, segment_ids: Array, num_segments: int) -> Array:
    """Exclusive prefix sum of ``values`` within each segment, in index order.

    ``segment_ids`` entries >= num_segments (or negative mapped there by the
    caller) contribute nothing and receive garbage prefixes — callers mask.
    """
    seg = jnp.clip(segment_ids, 0, num_segments)  # clip strays into a junk segment
    order = jnp.argsort(seg, stable=True)         # stable => row order inside segs
    v_sorted = values[order]
    seg_sorted = seg[order]
    incl = jnp.cumsum(v_sorted)
    excl = incl - v_sorted
    # Subtract each segment's starting offset: forward-fill the exclusive sum
    # observed at the first row of each segment. cumsum is non-decreasing for
    # non-negative values, so a running max implements the forward fill.
    is_first = jnp.concatenate(
        [jnp.ones((1,), dtype=bool), seg_sorted[1:] != seg_sorted[:-1]]
    )
    base = jnp.where(is_first, excl, -jnp.inf)
    base = jax.lax.associative_scan(jnp.maximum, base)
    prefix_sorted = excl - base
    out = jnp.zeros_like(values).at[order].set(prefix_sorted.astype(values.dtype))
    return out


def segment_sum(values: Array, segment_ids: Array, num_segments: int) -> Array:
    """Sum of ``values`` per segment -> [num_segments]."""
    seg = jnp.clip(segment_ids, 0, num_segments)
    return jnp.zeros((num_segments + 1,), values.dtype).at[seg].add(values)[:-1]


def segment_all(values: Array, segment_ids: Array, num_segments: int) -> Array:
    """Logical AND of ``values`` per segment (vacuously True) -> [num_segments]."""
    neg = segment_sum((~values).astype(jnp.int32), segment_ids, num_segments)
    return neg == 0


def segment_min(values: Array, segment_ids: Array, num_segments: int, fill) -> Array:
    seg = jnp.clip(segment_ids, 0, num_segments)
    out = jnp.full((num_segments + 1,), fill, values.dtype)
    return out.at[seg].min(values)[:-1]
