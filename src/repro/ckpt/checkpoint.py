"""Sharded, atomic, async checkpointing with resharding restore.

This is the framework's federation "VM migration" mechanism (DESIGN.md §2):
a job moves between pods/meshes by checkpoint + restore-with-resharding, the
tensorized analogue of CloudSim's VM image transfer.

Layout:   <dir>/step_<n>/arrays.npz  + manifest.json  (atomic via tmp+rename)
Async:    ``save_async`` snapshots to host memory synchronously (so training
          may mutate buffers) and writes on a background thread.
Reshard:  ``restore(..., shardings=...)`` device_puts every leaf to the new
          mesh's NamedSharding — restoring a 256-chip checkpoint onto a
          shrunken (elastic) mesh is the same call with the new mesh.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_like(template: Any, flat: dict[str, np.ndarray]) -> Any:
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {leaf.shape}"
            )
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None) -> str:
    """Synchronous atomic save. Returns the final step directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "n_arrays": len(flat),
            "bytes": int(sum(a.nbytes for a in flat.values())),
            **(extra or {}),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                     # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


class AsyncSaver:
    """Snapshot-now, write-later checkpointing (one in flight at a time)."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, ckpt_dir: str, step: int, tree: Any,
             extra: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # synchronous snapshot

        def _write():
            try:
                save(ckpt_dir, step, host_tree, extra)
            except BaseException as e:               # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and os.path.isfile(
            os.path.join(ckpt_dir, d, "manifest.json")
        )
    ]
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    template: Any,
    step: int | None = None,
    shardings: Any = None,
) -> tuple[Any, int]:
    """Load a checkpoint; optionally device_put to new (resharded) layout."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten_like(template, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, step
