"""repro.ckpt — atomic sharded checkpointing with async save + resharding restore."""
from repro.ckpt.checkpoint import AsyncSaver, latest_step, restore, save

__all__ = ["AsyncSaver", "latest_step", "restore", "save"]
