"""repro.train — optimizer + microbatched train step (built from scratch)."""
from repro.train.optimizer import OptConfig, adamw_init, adamw_update, cosine_schedule, global_norm
from repro.train.step import init_train_state, make_train_step

__all__ = [
    "OptConfig", "adamw_init", "adamw_update", "cosine_schedule",
    "global_norm", "init_train_state", "make_train_step",
]
