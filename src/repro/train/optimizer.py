"""AdamW + warmup-cosine schedule + global-norm clipping, from scratch.

Optimizer state mirrors the parameter tree (two moment trees), so the FSDP
PartitionSpecs derived for params apply verbatim to the state (ZeRO-style
sharded optimizer for free under GSPMD).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_schedule(cfg: OptConfig) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
        t = (step - cfg.warmup_steps) / jnp.maximum(
            cfg.total_steps - cfg.warmup_steps, 1
        )
        t = jnp.clip(t, 0.0, 1.0)
        cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * t)
        )
        return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)

    return lr


def adamw_init(params: Any) -> dict:
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)
    return {
        "mu": zeros(params),
        "nu": zeros(params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def _decay_mask(path) -> bool:
    """Weight decay on matrices only (no norms / biases / scalars)."""
    last = path[-1]
    name = getattr(last, "key", getattr(last, "name", ""))
    return "norm" not in str(name) and not str(name).endswith("_b")


def adamw_update(
    grads: Any, state: dict, params: Any, cfg: OptConfig
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    sched = cosine_schedule(cfg)
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = sched(state["step"])

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(path, p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, mu, nu: upd(path, p, g, mu, nu),
        params, grads, state["mu"], state["nu"],
    )
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
