"""Microbatched training step: grad-accumulation scan -> clip -> AdamW.

``make_train_step(model, opt_cfg, microbatches)`` returns a pure
``train_step(params, opt_state, batch) -> (params', opt_state', metrics)``
suitable for jit/pjit: the dry-run lowers exactly this function with the full
mesh shardings, and examples/train drivers jit it on CPU.

Gradient accumulation splits the per-device batch into ``microbatches``
sequential slices (lax.scan), shrinking peak activation memory by that factor
while keeping one weight update per step.

``param_shardings`` (a NamedSharding tree matching params) pins the gradient
accumulator and per-microbatch grads to the FSDP layout — without it GSPMD
tends to replicate the f32 accumulator per device, which alone overflows HBM
for multi-billion-param models (measured: 27 GB -> fits, see EXPERIMENTS.md
§Dry-run).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.train.optimizer import OptConfig, adamw_init, adamw_update


def _split_mb(batch: dict, m: int) -> dict:
    """Reshape [B, ...] -> [m, B/m, ...] (positions: batch is axis 1)."""

    def split(key, x):
        if key == "positions" and x.ndim == 3:           # (3, B, S)
            b = x.shape[1]
            assert b % m == 0, f"batch {b} not divisible by microbatches {m}"
            return jnp.moveaxis(
                x.reshape(x.shape[0], m, b // m, x.shape[2]), 1, 0
            )
        b = x.shape[0]
        assert b % m == 0, f"batch {b} not divisible by microbatches {m}"
        return x.reshape(m, b // m, *x.shape[1:])

    return {k: split(k, v) for k, v in batch.items()}


def make_train_step(
    model,
    opt_cfg: OptConfig,
    microbatches: int = 1,
    param_shardings: Any = None,
):
    loss_fn = lambda p, b: model.loss(p, b)

    def constrain(tree):
        if param_shardings is None:
            return tree
        return jax.tree.map(
            jax.lax.with_sharding_constraint, tree, param_shardings
        )

    def train_step(params, opt_state, batch: dict[str, Any]):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = constrain(grads)
        else:
            mbs = _split_mb(batch, microbatches)

            def acc(carry, mb):
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                tot_l, tot_g = carry
                new_g = constrain(
                    jax.tree.map(jnp.add, tot_g, constrain(grads))
                )
                return (tot_l + loss, new_g), None

            zero = (
                jnp.zeros((), jnp.float32),
                constrain(jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )),
            )
            (loss, grads), _ = jax.lax.scan(acc, zero, mbs)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        params, opt_state, metrics = adamw_update(
            grads, opt_state, params, opt_cfg
        )
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def init_train_state(model, key, opt_cfg: OptConfig):
    params = model.init(key)
    return params, adamw_init(params)
