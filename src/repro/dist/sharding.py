"""Sharding rule trees: parameter/input PartitionSpecs from path+shape rules.

The mesh mapping (DESIGN.md §6) assigns every array dimension a *logical*
axis — ``tp`` (tensor parallel, the fast intra-pod ``"model"`` axis),
``fsdp`` (parameter sharding over the ``"data"`` axis), ``batch`` (data
parallelism over ``("pod", "data")``) — and resolves logical axes to mesh
axes *per leaf* with a divisibility fallback: candidate axes are examined
left-to-right and an axis is taken only if the dimension stays divisible by
the accumulated axis product (axes that don't fit are skipped), so a
dimension no candidate fits falls back to replication.  No mesh axis is ever used twice within one spec.  This is what
lets ONE rule table serve every architecture in the pool on any mesh — the
16x16 production pod, the 2x16x16 multi-pod mesh, and the 1-device CPU test
mesh — without per-model spec tables (tests/test_sharding.py asserts
validity for all archs).

Rules are pattern-matched on the parameter *path* (``"/"``-joined tree keys,
``re.search``) and, where one name is shared by different tensor ranks
(``mlp/w_gate`` is ``[L, D, F]`` dense but ``[L, E, D, F]`` MoE), on the
leaf's ndim.  Templates are right-aligned: leading stacking axes (the
scan-over-periods ``L`` axis) are implicitly replicated.
"""
from __future__ import annotations

import dataclasses
import re

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Rules:
    """Mesh-axis roles for one (mesh, strategy) pair.

    ``tp``/``dp`` name single mesh axes (or None); ``batch`` is every axis
    carrying data parallelism, slowest (inter-pod) first; ``fsdp`` is the
    axis set parameters shard over.
    """

    tp: str | None
    dp: str | None
    batch: tuple[str, ...]
    fsdp: tuple[str, ...]


def _axis_sizes(mesh) -> dict:
    """axis name -> size, for Mesh and AbstractMesh alike."""
    return dict(mesh.shape)


def rules_for_mesh(mesh, strategy: str = "2d") -> Rules:
    """Role assignment for a mesh.

    ``"2d"``: ``model`` is tensor-parallel, ``data`` (and ``pod`` when
    present) carry batch + FSDP.  ``"fsdp"``: no tensor parallelism — every
    axis is data parallel and parameters shard over all of them (consumers
    such as models/moe.py check ``rules.tp is None`` to skip EP).
    """
    names = tuple(mesh.axis_names)
    if strategy == "2d":
        tp = "model" if "model" in names else None
        batch = tuple(a for a in names if a != "model")
        fsdp = ("data",) if "data" in names else batch
        dp = "data" if "data" in names else (batch[0] if batch else None)
        return Rules(tp=tp, dp=dp, batch=batch, fsdp=fsdp)
    if strategy == "fsdp":
        return Rules(tp=None, dp=names[0] if names else None,
                     batch=names, fsdp=names)
    raise ValueError(f"unknown sharding strategy {strategy!r}: '2d' | 'fsdp'")


# ---------------------------------------------------------------------------
# logical -> mesh axis resolution (the divisibility fallback)
# ---------------------------------------------------------------------------

def _resolve_dim(dim: int, candidates: tuple[str, ...], sizes: dict,
                 used: set):
    """Examine candidate axes left-to-right; take each axis only if ``dim``
    stays divisible by the accumulated product (non-fitting axes are
    skipped, not a hard stop).

    Returns a spec entry: an axis name, a tuple of names, or None (fallback
    to replication).  Axes already used in this spec are skipped — the
    no-axis-reuse invariant.
    """
    picked: list[str] = []
    prod = 1
    for a in candidates:
        if a is None or a not in sizes or a in used:
            continue
        if dim % (prod * sizes[a]) == 0:
            picked.append(a)
            prod *= sizes[a]
    for a in picked:
        used.add(a)
    if not picked:
        return None
    return picked[0] if len(picked) == 1 else tuple(picked)


def _spec_from_template(shape, template, rules: Rules, sizes: dict) -> P:
    """Right-align ``template`` on ``shape`` and resolve logical axes."""
    if len(template) > len(shape):
        template = template[len(template) - len(shape):]
    entries: list = [None] * (len(shape) - len(template))
    used: set = set()
    for dim, logical in zip(shape[len(shape) - len(template):], template):
        if logical is None:
            entries.append(None)
        elif logical == "tp":
            entries.append(_resolve_dim(dim, (rules.tp,), sizes, used))
        elif logical == "fsdp":
            entries.append(_resolve_dim(dim, rules.fsdp, sizes, used))
        elif logical == "batch":
            entries.append(_resolve_dim(dim, rules.batch, sizes, used))
        else:
            raise ValueError(f"unknown logical axis {logical!r}")
    return P(*entries)


# ---------------------------------------------------------------------------
# rule tables
# ---------------------------------------------------------------------------

def _kv_cache_template(leaf):
    """KV caches [..., B, H, S, Dh]: shard heads over tp when the head count
    divides, else the LENGTH axis (the flash-decoding length-sharded layout
    models/attention.py switches on), else neither."""

    def build(rules: Rules, sizes: dict):
        ntp = sizes.get(rules.tp, 1) if rules.tp else 1
        H, S = leaf.shape[-3], leaf.shape[-2]
        if ntp > 1 and H % ntp == 0:
            return ("batch", "tp", None, None)
        if ntp > 1 and S % ntp == 0:
            return ("batch", None, "tp", None)
        return ("batch", None, None, None)

    return build

# (regex, template) — first match wins.  A dict template selects by leaf
# ndim (the "shape" half of path/shape matching); a callable receives the
# leaf and returns a builder(rules, sizes) -> template.
_PARAM_RULES = (
    (r"(^|/)embed$", ("tp", "fsdp")),
    (r"(^|/)head$", ("fsdp", "tp")),
    (r"(enc_pos|dec_pos)$", ("fsdp", "tp")),
    (r"mlp/router$", ()),
    (r"mlp/w_(gate|up)$", {4: ("tp", None, "fsdp"),     # MoE [L, E, D, F]
                           3: ("fsdp", "tp"),           # dense [L, D, F]
                           2: ("fsdp", "tp")}),
    (r"mlp/w_down$", {4: ("tp", "fsdp", None),          # MoE [L, E, F, D]
                      3: ("tp", "fsdp"),
                      2: ("tp", "fsdp")}),
    (r"(wq|wk|wv|w_z|w_x|w_B|w_C|w_dt|w_gate|w_up)$", ("fsdp", "tp")),
    (r"(wo|out_proj|w_down)$", ("tp", "fsdp")),
    (r"conv_w$", (None, "tp")),
)

_INPUT_RULES = (
    (r"(^|/)(tokens|labels)$", ("batch", None)),
    (r"positions$", (None, "batch", None)),
    (r"(frames|frontend_embeds)$", ("batch", None, "tp")),
    (r"(^|/)token$", ("batch", None)),
    (r"(^|/)pos$", ("batch",)),
    (r"caches.*/(k|v|ck|cv)$", _kv_cache_template),
    (r"caches.*/conv$", (None, "batch", None, "tp")),
    (r"caches.*/state$", (None, "batch", None, None, "tp")),
)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _match_template(table, path: str, leaf):
    for pattern, template in table:
        if re.search(pattern, path):
            if callable(template) and not isinstance(template, tuple):
                return template(leaf)
            if isinstance(template, dict):
                return template.get(leaf.ndim, ())
            return template
    return ()  # unmatched -> replicate


def _pspec_tree(tree, mesh, strategy: str, table) -> object:
    rules = rules_for_mesh(mesh, strategy)
    sizes = _axis_sizes(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for path, leaf in flat:
        template = _match_template(table, _path_str(path), leaf)
        if callable(template) and not isinstance(template, tuple):
            template = template(rules, sizes)
        specs.append(_spec_from_template(leaf.shape, template, rules, sizes))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_pspec_tree(shapes, mesh, strategy: str = "2d"):
    """PartitionSpec tree for a parameter pytree (ShapeDtypeStructs/arrays)."""
    return _pspec_tree(shapes, mesh, strategy, _PARAM_RULES)


def input_pspec_tree(specs, mesh, strategy: str = "2d"):
    """PartitionSpec tree for Model.input_specs trees (batch/caches/token/pos)."""
    return _pspec_tree(specs, mesh, strategy, _INPUT_RULES)


def campaign_pspec_tree(batched, mesh, axis: str = "data"):
    """PartitionSpec tree sharding a stacked-Scenario campaign's leading
    batch axis over ``mesh[axis]``, every other dimension replicated.

    Reuses the same divisibility fallback as the model rule tables
    (``_resolve_dim``): a leading dimension ``mesh[axis]`` does not divide
    resolves to ``None`` (replicated), which ``core/campaign.py`` treats as
    a hard error for the campaign axis — silently replicating a million-row
    sweep onto every device is never what a caller wants.  Works on arrays
    and on ``jax.eval_shape`` trees alike (only ``.shape`` is read).
    """
    sizes = _axis_sizes(mesh)

    def spec(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        entry = _resolve_dim(shape[0], (axis,), sizes, set())
        return P(entry, *([None] * (len(shape) - 1)))

    return jax.tree.map(spec, batched)


def named(mesh, pspec_tree):
    """PartitionSpec tree -> NamedSharding tree on a concrete mesh."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
