"""Activation sharding: a context, a state query, and one constraint helper.

Model code never names mesh axes — it annotates activations with *logical*
axes (``"batch"``, ``"seq"``, ``"model"``, None) via ``shard_act``.  Outside
an ``activation_shardings`` context ``shard_act`` is an exact no-op (returns
its argument unchanged — the single-device test/CPU path adds zero ops to
the jaxpr).  Inside the context it resolves logical axes against the active
(mesh, rules) with the same divisibility fallback as the parameter rules and
emits ``with_sharding_constraint``.

``current_state()`` exposes the raw ``(mesh, rules, sequence_parallel)``
triple for code that needs more than a constraint — models/moe.py picks its
EP schedule from it, models/attention.py switches to the length-sharded
flash-decoding path.  The state is trace-time only (a Python global, not a
traced value): enter the context around ``jit``/``lower`` calls, as
launch/dryrun.py does.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import Rules, _axis_sizes, _resolve_dim, rules_for_mesh

_STATE: tuple | None = None  # (mesh, Rules, sequence_parallel)


def current_state() -> tuple | None:
    """The active (mesh, rules, sequence_parallel) triple, or None."""
    return _STATE


@contextmanager
def activation_shardings(mesh, rules: Rules | None = None, *,
                         sequence_parallel: bool = False,
                         strategy: str = "2d"):
    """Activate activation sharding for the enclosed trace/lower/jit calls."""
    global _STATE
    if rules is None:
        rules = rules_for_mesh(mesh, strategy)
    prev = _STATE
    _STATE = (mesh, rules, bool(sequence_parallel))
    try:
        yield _STATE
    finally:
        _STATE = prev


def shard_act(x, logical_axes):
    """Constrain ``x`` to the active sharding; identity when no state is set.

    ``logical_axes``: one entry per dim — ``"batch"`` (data axes),
    ``"model"`` (tensor-parallel axis), ``"seq"`` (sequence parallelism:
    the tp axis, active only when the context enabled it), or None.
    """
    state = _STATE
    if state is None:
        return x
    mesh, rules, seq_par = state
    sizes = _axis_sizes(mesh)
    used: set = set()
    entries = []
    for dim, logical in zip(x.shape, logical_axes):
        if logical is None:
            entries.append(None)
        elif logical == "batch":
            entries.append(_resolve_dim(dim, rules.batch, sizes, used))
        elif logical == "model":
            entries.append(_resolve_dim(dim, (rules.tp,), sizes, used))
        elif logical == "seq":
            cand = (rules.tp,) if seq_par else ()
            entries.append(_resolve_dim(dim, cand, sizes, used))
        else:
            raise ValueError(
                f"unknown logical activation axis {logical!r}: "
                "'batch' | 'seq' | 'model' | None"
            )
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries))
    )
