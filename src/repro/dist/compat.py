"""shard_map across jax versions — the single import point for the repo.

jax >= 0.6 exposes ``jax.shard_map(..., check_vma=...)``; jax 0.4.x (this
container: 0.4.37, see DESIGN.md) has ``jax.experimental.shard_map.shard_map``
with the older ``check_rep`` spelling.  Both call sites in the tree
(models/attention.py flash-decoding, models/moe.py EP dispatch,
core/campaign.py sharded campaigns) run with replication checking disabled:
their out_specs intentionally declare values replicated that the static
checker cannot prove replicated (log-sum-exp merges computed identically on
every shard from all-gathered stats).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.6
    _shard_map = jax.shard_map
    _CHECK_OFF = {"check_vma": False}
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_OFF = {"check_rep": False}


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-portable shard_map with replication checking disabled."""
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **_CHECK_OFF
    )
