"""repro.dist — sharding rule trees + activation sharding (DESIGN.md §6).

Two halves:

* ``repro.dist.sharding`` — *static* layout: PartitionSpec trees derived
  from path/shape rule tables with divisibility fallbacks
  (``param_pspec_tree`` / ``input_pspec_tree`` / ``rules_for_mesh``), and
  ``named`` to bind them to a concrete mesh.
* ``repro.dist.act_sharding`` — *dynamic* layout: the
  ``activation_shardings`` context models consult while tracing
  (``shard_act`` constraints, ``current_state`` for schedule selection).

``repro.dist.compat`` carries the jax-version shard_map shim used by every
shard_map call site in the tree.
"""
from repro.dist import act_sharding, compat, sharding
from repro.dist.act_sharding import activation_shardings, current_state, shard_act
from repro.dist.sharding import (
    Rules,
    input_pspec_tree,
    named,
    param_pspec_tree,
    rules_for_mesh,
)

__all__ = [
    "Rules",
    "act_sharding",
    "activation_shardings",
    "compat",
    "current_state",
    "input_pspec_tree",
    "named",
    "param_pspec_tree",
    "rules_for_mesh",
    "shard_act",
    "sharding",
]
