"""Elastic / fault-tolerant runtime: the CloudCoordinator applied to training.

Mapping (DESIGN.md §2): CloudSim's coordinator senses datacenter health and
migrates VMs; here the coordinator senses worker health (heartbeats /
injected failures), and "migration" is checkpoint-restore onto the surviving
mesh — a training job's VM image is its (params, opt_state) checkpoint.

``ElasticRunner`` drives run_training under supervision:
  1. run until failure (or completion),
  2. on failure: shrink the logical resource set (simulating lost nodes),
  3. restore the latest checkpoint — restore() re-device_puts onto whatever
     mesh is now available (resharding restore),
  4. continue training; repeat up to ``max_restarts``.

The CloudSim engine itself is used to *plan* the restart: the coordinator
simulates the remaining work as cloudlets over the surviving hosts to decide
whether finishing on the shrunken cluster beats waiting for repair (the
paper's "evaluate before deploying" loop, pointed at ourselves).
"""
from __future__ import annotations

import dataclasses

import jax

from repro.ckpt import latest_step
from repro.core import SPACE_SHARED, Scenario, scenarios as builders, simulate
from repro.launch.train import run_training


@dataclasses.dataclass
class RestartDecision:
    finish_on_survivors_s: float
    wait_for_repair_s: float
    choice: str


def plan_restart(
    steps_remaining: int,
    step_time_s: float,
    n_workers: int,
    n_survivors: int,
    repair_time_s: float,
) -> RestartDecision:
    """CloudSim-planned restart: simulate 'remaining work on survivors' vs
    'wait for repair, then full speed' and pick the shorter makespan."""
    work_mi = steps_remaining * step_time_s * 1000.0  # 1000 MIPS host = 1x

    def makespan(n_hosts: int, delay: float) -> float:
        hosts = builders.uniform_hosts(1, max(n_workers, 1), cores=1,
                                       mips=1000.0, ram_mb=1e6)
        import numpy as _np
        exists = _np.zeros((1, max(n_workers, 1)), bool)
        exists[0, :n_hosts] = True
        hosts = hosts.replace(exists=jax.numpy.asarray(exists))
        vms = builders.uniform_vms(n_hosts, ram_mb=1.0, bw_mbps=1.0)
        # data-parallel training: work splits evenly across workers
        cl = builders.make_cloudlets(
            _np.arange(n_hosts),
            _np.full(n_hosts, work_mi / max(n_hosts, 1)),
            _np.full(n_hosts, delay),
            input_mb=0.0, output_mb=0.0,
        )
        scn = Scenario(hosts=hosts, vms=vms, cloudlets=cl,
                       market=builders.uniform_market(1),
                       policy=builders.make_policy(
                           host_policy=SPACE_SHARED, vm_policy=SPACE_SHARED,
                           core_reserving=True, horizon=1e9))
        return float(simulate(scn).makespan)

    on_survivors = makespan(n_survivors, 0.0)
    after_repair = makespan(n_workers, repair_time_s)
    choice = "survivors" if on_survivors <= after_repair else "wait_for_repair"
    return RestartDecision(on_survivors, after_repair, choice)


class ElasticRunner:
    def __init__(self, cfg, ckpt_dir: str, *, steps: int = 60,
                 global_batch: int = 8, seq_len: int = 64,
                 ckpt_every: int = 10, max_restarts: int = 3,
                 n_workers: int = 4, repair_time_s: float = 600.0):
        self.cfg = cfg
        self.ckpt_dir = ckpt_dir
        self.kw = dict(steps=steps, global_batch=global_batch,
                       seq_len=seq_len, ckpt_every=ckpt_every,
                       ckpt_dir=ckpt_dir)
        self.max_restarts = max_restarts
        self.n_workers = n_workers
        self.repair_time_s = repair_time_s
        self.events: list[dict] = []

    def run(self, fail_at_steps: list[int] | None = None) -> dict:
        fail_at = list(fail_at_steps or [])
        survivors = self.n_workers
        restarts = 0
        while True:
            inject = fail_at.pop(0) if fail_at else None
            try:
                out = run_training(self.cfg, fail_at_step=inject, **self.kw)
                self.events.append({"kind": "finished",
                                    "final_loss": out["final_loss"]})
                return {"result": out, "events": self.events,
                        "restarts": restarts}
            except RuntimeError as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                survivors = max(survivors - 1, 1)
                ck = latest_step(self.ckpt_dir)
                remaining = self.kw["steps"] - (ck or 0)
                plan = plan_restart(remaining, 1.0, self.n_workers,
                                    survivors, self.repair_time_s)
                self.events.append({
                    "kind": "failure", "error": str(e),
                    "resume_step": ck, "survivors": survivors,
                    "plan": dataclasses.asdict(plan),
                })
                print(f"[elastic] failure ({e}); resume from step {ck} on "
                      f"{survivors} workers (plan: {plan.choice})",
                      flush=True)
                # loop: run_training resumes from the latest checkpoint
