import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the full-size model config (ShapeDtypeStruct only —
nothing is allocated), derives parameter/input shardings from repro.dist,
lowers the step function against the production mesh, compiles it, and
records ``memory_analysis()`` (proves it fits), ``cost_analysis()`` and the
collective schedule (feeds EXPERIMENTS.md §Roofline).

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

Results are cached as JSON under results/dryrun/ so the full sweep is
resumable.
"""
import argparse
import glob
import json
import shutil
import tempfile
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis import hlo_walk, memory as memest, roofline
from repro.configs import ARCH_IDS, get_config
from repro.dist import input_pspec_tree, named, param_pspec_tree
from repro.dist.act_sharding import activation_shardings
from repro.launch.mesh import make_production_mesh
from repro.models import ALL_SHAPES, build_model, shape_applicable
from repro.models.config import ShapeSpec
from repro.train import OptConfig, adamw_init, make_train_step

RESULTS_DIR = "results/dryrun"


def _shape_by_name(name: str) -> ShapeSpec:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def lower_cell(
    arch: str,
    shape: ShapeSpec,
    mesh,
    *,
    microbatches: int = 4,
    donate: bool = True,
    extra_cfg: dict | None = None,
    sequence_parallel: bool = False,
    master_bf16: bool = False,
    moments_bf16: bool = False,
    strategy: str = "2d",
):
    """Build + lower + compile one cell. Returns (compiled, lowered, meta)."""
    cfg = get_config(arch, dtype="bfloat16")
    if extra_cfg:
        import dataclasses
        extra = dict(extra_cfg)
        capf = extra.pop("moe_capacity_factor", None)
        if capf is not None and cfg.moe is not None:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=capf))
        cfg = dataclasses.replace(cfg, **extra)
    model = build_model(cfg)
    pspec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if master_bf16 or shape.kind != "train":
        # store weights bf16 (training: bf16 master + f32 moments; serving:
        # bf16 deployment weights)
        pspec = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                l.shape,
                jnp.bfloat16 if l.dtype == jnp.float32 else l.dtype),
            pspec,
        )
    param_specs = param_pspec_tree(pspec, mesh, strategy)
    param_sh = named(mesh, param_specs)

    if shape.kind == "train":
        opt_shape = jax.eval_shape(adamw_init, pspec)
        if moments_bf16:
            opt_shape = {
                "mu": jax.tree.map(
                    lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16),
                    opt_shape["mu"]),
                "nu": jax.tree.map(
                    lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16),
                    opt_shape["nu"]),
                "step": opt_shape["step"],
            }
        opt_specs = {
            "mu": param_specs, "nu": param_specs,
            "step": jax.sharding.PartitionSpec(),
        }
        opt_sh = named(mesh, opt_specs)
        specs = model.input_specs(shape)
        in_sh = named(mesh, input_pspec_tree(specs, mesh, strategy))
        step = make_train_step(
            model, OptConfig(), microbatches=microbatches,
            param_shardings=param_sh,
        )

        fn = jax.jit(
            step,
            in_shardings=(param_sh, opt_sh, in_sh["batch"]),
            donate_argnums=(0, 1) if donate else (),
        )
        with mesh, activation_shardings(
                mesh, sequence_parallel=sequence_parallel,
                strategy=strategy):
            lowered = fn.lower(pspec, opt_shape, specs["batch"])
    elif shape.kind == "prefill":
        specs = model.input_specs(shape)
        in_sh = named(mesh, input_pspec_tree(specs, mesh, strategy))

        def prefill_fn(params, batch):
            return model.prefill(params, batch, shape.seq_len)

        fn = jax.jit(prefill_fn, in_shardings=(param_sh, in_sh["batch"]))
        with mesh, activation_shardings(
                mesh, sequence_parallel=sequence_parallel,
                strategy=strategy):
            lowered = fn.lower(pspec, specs["batch"])
    else:  # decode
        specs = model.input_specs(shape)
        in_sh = named(mesh, input_pspec_tree(specs, mesh, strategy))

        def decode_fn(params, caches, token, pos):
            return model.decode_step(params, caches, token, pos)

        fn = jax.jit(
            decode_fn,
            in_shardings=(param_sh, in_sh["caches"], in_sh["token"],
                          in_sh["pos"]),
            donate_argnums=(1,) if donate else (),
        )
        with mesh, activation_shardings(
                mesh, sequence_parallel=sequence_parallel):
            lowered = fn.lower(pspec, specs["caches"], specs["token"],
                               specs["pos"])

    # Dump the post-SPMD-partitioning module: the CPU backend's float
    # normalization upcasts bf16 collectives to f32 in the FINAL module
    # (2x inflation vs the TPU target), so collective accounting reads the
    # pre-normalization partitioned HLO instead.
    dump_dir = tempfile.mkdtemp(prefix="hlo_dump_")
    compiled = lowered.compile(compiler_options={
        "xla_dump_to": dump_dir,
        "xla_dump_hlo_pass_re": "spmd-partitioning",
    })
    spmd_hlo = None
    cands = glob.glob(os.path.join(dump_dir, "*after_spmd-partitioning*.txt"))
    if cands:
        biggest = max(cands, key=os.path.getsize)
        with open(biggest) as f:
            spmd_hlo = f.read()
    shutil.rmtree(dump_dir, ignore_errors=True)
    return compiled, lowered, {"cfg": cfg, "model": model,
                               "spmd_hlo": spmd_hlo}


def run_cell(arch: str, shape: ShapeSpec, mesh_kind: str,
             microbatches: int = 4, extra_cfg: dict | None = None,
             tag: str = "", sequence_parallel: bool = False,
             master_bf16: bool = False, moments_bf16: bool = False,
             strategy: str = "2d") -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape.name, "mesh": mesh_kind,
                "skipped": why}

    t0 = time.time()
    compiled, lowered, meta = lower_cell(
        arch, shape, mesh, microbatches=microbatches, extra_cfg=extra_cfg,
        sequence_parallel=sequence_parallel, master_bf16=master_bf16,
        moments_bf16=moments_bf16, strategy=strategy,
    )
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    walk = hlo_walk.analyze_hlo(hlo, default_group=n_chips)
    if meta.get("spmd_hlo"):
        # Two views of the collective schedule, each an overcount in one
        # direction: the FINAL module is dtype-inflated (CPU float
        # normalization upcasts bf16 collectives to f32; TPU would not),
        # the POST-SPMD module predates all-reduce combining (op-inflated).
        # Per kind we take the smaller — a tight upper bound either way.
        walk_spmd = hlo_walk.analyze_hlo(meta["spmd_hlo"],
                                         default_group=n_chips)
        for k in set(walk.coll_eff_by_kind) | set(walk_spmd.coll_eff_by_kind):
            a = walk.coll_eff_by_kind.get(k, float("inf"))
            b = walk_spmd.coll_eff_by_kind.get(k, float("inf"))
            if b < a:
                walk.coll_eff_by_kind[k] = b
                walk.coll_raw[k] = walk_spmd.coll_raw.get(k, 0)
                walk.coll_counts[k] = walk_spmd.coll_counts.get(k, 0)
    est = memest.estimate(
        meta["model"], meta["cfg"], shape, mesh, microbatches=microbatches,
        sequence_parallel=sequence_parallel, master_bf16=master_bf16,
        moments_bf16=moments_bf16, strategy=strategy,
    )
    rl = roofline.analyze_walk(
        walk, est, n_chips, roofline.model_flops_for(meta["cfg"], shape)
    )
    out = {
        "arch": arch,
        "shape": shape.name,
        "mesh": mesh_kind,
        "n_chips": int(n_chips),
        "compile_s": compile_s,
        "microbatches": microbatches if shape.kind == "train" else None,
        "tag": tag,
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "peak_bytes_est": int(
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0)
            ),
        },
        "params": int(meta["cfg"].param_count()),
        "active_params": int(meta["cfg"].active_param_count()),
        "memory_model": est.as_dict(),
        "xla_cost_raw": {k: float(v) for k, v in cost.items()
                         if isinstance(v, (int, float))},
        "roofline": rl.as_dict(),
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=[s.name for s in ALL_SHAPES])
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in ALL_SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, _shape_by_name(args.shape))]

    for arch, shape in cells:
        path = os.path.join(
            RESULTS_DIR, f"{arch}__{shape.name}__{args.mesh}.json"
        )
        if os.path.exists(path) and not args.force:
            print(f"[skip cached] {arch} x {shape.name} x {args.mesh}")
            continue
        print(f"[dryrun] {arch} x {shape.name} x {args.mesh} ...", flush=True)
        try:
            out = run_cell(arch, shape, args.mesh,
                           microbatches=args.microbatches)
        except Exception:
            out = {
                "arch": arch, "shape": shape.name, "mesh": args.mesh,
                "error": traceback.format_exc(),
            }
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
        if "error" in out:
            print(f"  ERROR (see {path})")
            print("  " + out["error"].strip().splitlines()[-1])
        elif "skipped" in out:
            print(f"  SKIPPED: {out['skipped']}")
        else:
            r = out["roofline"]
            print(
                "  ok compile=%.0fs resid=%.2fGB xla_tmp=%.2fGB comp=%.1fms "
                "memT=%.1fms coll=%.1fms bneck=%s MFU-bound=%.1f%%"
                % (
                    out["compile_s"],
                    out["memory_model"]["residency_bytes"] / 1e9,
                    out["memory"]["temp_bytes"] / 1e9,
                    r["compute_s"] * 1e3,
                    r["memory_s"] * 1e3,
                    r["collective_s"] * 1e3,
                    r["bottleneck"],
                    100 * r["roofline_fraction"],
                ),
                flush=True,
            )


if __name__ == "__main__":
    main()
