"""Production mesh construction (pure function — importing this module never
touches jax device state).

Single pod:  (data=16, model=16)          = 256 chips (one v5e pod)
Multi-pod:   (pod=2, data=16, model=16)   = 512 chips

The "pod" axis carries data parallelism only (params replicated across pods,
gradients all-reduced over pod x data) — the cheapest traffic to put on the
slow inter-pod links.  See DESIGN.md §6.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    try:
        return jax.make_mesh(shape, axes)
    except ValueError:
        # device count != mesh size (e.g. 512 host devices, 256-chip mesh):
        # take a prefix — fine for dry-run lowering purposes.
        devs = np.array(jax.devices()[:n]).reshape(shape)
        return Mesh(devs, axes)


def make_host_mesh(shape: tuple[int, ...] = (1, 1),
                   axes: tuple[str, ...] = ("data", "model")) -> Mesh:
    """Tiny mesh over whatever devices exist (CPU tests/examples)."""
    n = int(np.prod(shape))
    devs = np.array(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, axes)
