"""Training driver: ``python -m repro.launch.train --arch <id> [options]``.

Runs real steps on whatever devices exist (CPU here; the same code path jits
under the production mesh on TPU).  Checkpoints periodically (async), resumes
from the latest checkpoint if present, and logs loss/throughput.

This is the end-to-end example driver scaled down: examples/train_100m.py
invokes it with a ~100M-param config for a few hundred steps.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import AsyncSaver, latest_step, restore
from repro.configs import ARCH_IDS, get_config
from repro.data import ShardedLoader
from repro.models import build_model
from repro.train import OptConfig, adamw_init, make_train_step


def run_training(
    cfg,
    *,
    steps: int = 200,
    global_batch: int = 8,
    seq_len: int = 128,
    lr: float = 3e-4,
    microbatches: int = 1,
    ckpt_dir: str | None = None,
    ckpt_every: int = 100,
    log_every: int = 10,
    seed: int = 0,
    fail_at_step: int | None = None,   # fault-injection hook (elastic demo)
) -> dict:
    model = build_model(cfg)
    opt_cfg = OptConfig(lr=lr, warmup_steps=max(steps // 20, 5),
                        total_steps=steps)
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    opt_state = adamw_init(params)
    start_step = 0

    if ckpt_dir and latest_step(ckpt_dir) is not None:
        (params, opt_state), start_step = restore(
            ckpt_dir, (params, opt_state)
        )
        print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(model, opt_cfg, microbatches))
    loader = ShardedLoader(cfg.vocab, global_batch, seq_len, seed=seed)
    saver = AsyncSaver()

    losses: list[float] = []
    t0 = time.perf_counter()
    tokens = 0
    try:
        for step, batch in zip(range(start_step, steps), loader):
            if fail_at_step is not None and step == fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = step_fn(params, opt_state, jb)
            losses.append(float(metrics["loss"]))
            tokens += global_batch * seq_len
            if log_every and step % log_every == 0:
                dt = time.perf_counter() - t0
                print(
                    f"[train] step={step} loss={losses[-1]:.4f} "
                    f"lr={float(metrics['lr']):.2e} "
                    f"gnorm={float(metrics['grad_norm']):.3f} "
                    f"tok/s={tokens / max(dt, 1e-9):.0f}",
                    flush=True,
                )
            if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
                saver.save(ckpt_dir, step + 1, (params, opt_state))
    finally:
        loader.close()
        saver.wait()
    if ckpt_dir:
        saver.save(ckpt_dir, steps, (params, opt_state))
        saver.wait()
    return {
        "losses": losses,
        "final_loss": losses[-1] if losses else float("nan"),
        "params": params,
        "steps_run": len(losses),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    out = run_training(
        cfg, steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq_len, lr=args.lr, microbatches=args.microbatches,
        ckpt_dir=args.ckpt_dir, seed=args.seed,
    )
    print(f"[train] done: {out['steps_run']} steps, "
          f"final loss {out['final_loss']:.4f} "
          f"(ln V = {np.log(cfg.vocab):.2f})")


if __name__ == "__main__":
    main()
