"""Serving driver: ``python -m repro.launch.serve --arch <id> [--smoke]``.

Boots the continuous-batching engine (serving/engine.py) with the CloudSim
predictive scheduler, feeds it a synthetic Poisson-ish request trace, and
reports per-request turnaround + makespan — the paper's Table-1 metrics
measured on the real serving stack rather than in simulation (EXPERIMENTS.md
compares the two).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.serving import ServingEngine


def run_serving(
    cfg,
    *,
    n_requests: int = 8,
    n_slots: int = 2,
    max_len: int = 96,
    prompt_len: int = 16,
    max_new_tokens: int = 16,
    policy: int = 0,
    replan_every: int = 0,
    seed: int = 0,
) -> dict:
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    eng = ServingEngine(model, params, n_slots=n_slots, max_len=max_len,
                        policy=policy, replan_every=replan_every)
    rng = np.random.default_rng(seed)
    for _ in range(n_requests):
        eng.submit(rng.integers(0, cfg.vocab, size=prompt_len),
                   max_new_tokens=max_new_tokens)
    reqs = eng.run_until_drained()
    tats = [r.finish_time - r.arrival for r in reqs if r.done]
    return {
        "all_done": all(r.done for r in reqs),
        "mean_turnaround_steps": float(np.mean(tats)) if tats else float("nan"),
        "makespan_steps": eng.steps,
        "final_policy": eng.sched.policy,
        "requests": reqs,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--policy", type=int, default=0,
                    help="0=space-shared 1=time-shared")
    ap.add_argument("--replan-every", type=int, default=0,
                    help=">0: re-simulate the queue every N steps and switch "
                         "policy to the predicted-better one")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    out = run_serving(cfg, n_requests=args.requests, n_slots=args.slots,
                      max_len=args.max_len, policy=args.policy,
                      replan_every=args.replan_every)
    print(f"[serve] done={out['all_done']} "
          f"meanTAT={out['mean_turnaround_steps']:.1f} steps "
          f"makespan={out['makespan_steps']} steps "
          f"policy={out['final_policy']}")


if __name__ == "__main__":
    main()
