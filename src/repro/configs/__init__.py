"""Config registry: every assigned architecture + the paper's own scenarios.

``get_config(arch_id)`` returns the exact published configuration;
``get_config(arch_id, smoke=True)`` returns the reduced same-family variant
used by the CPU smoke tests.  ``--arch <id>`` on every launcher resolves
through this registry.  The paper's own experiment scenarios (CloudSim
Figures 4/7-10, Table 1) live in repro.core.scenarios and are re-exported
here for symmetry.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig
from repro.core import scenarios as cloudsim_scenarios

ARCH_IDS = (
    "phi3-mini-3.8b",
    "qwen3-32b",
    "gemma2-27b",
    "internlm2-1.8b",
    "jamba-v0.1-52b",
    "whisper-large-v3",
    "mamba2-130m",
    "qwen3-moe-235b-a22b",
    "granite-moe-1b-a400m",
    "qwen2-vl-72b",
)

_MODULES = {
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "qwen3-32b": "qwen3_32b",
    "gemma2-27b": "gemma2_27b",
    "internlm2-1.8b": "internlm2_1_8b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "whisper-large-v3": "whisper_large_v3",
    "mamba2-130m": "mamba2_130m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen2-vl-72b": "qwen2_vl_72b",
}


def get_config(arch: str, *, smoke: bool = False, dtype: str | None = None) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {', '.join(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    fn = mod.smoke_config if smoke else mod.config
    if dtype is not None:
        return fn(dtype=dtype)
    return fn()


__all__ = ["ARCH_IDS", "get_config", "cloudsim_scenarios"]
