"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536; Mamba:attention 7:1 interleave (attention on layer 8k+7), MoE
16 experts top-2 on every other layer [arXiv:2403.19887].

Note (DESIGN.md §Arch-applicability): Jamba v0.1 uses Mamba-1 selective-scan
layers (d_state=16); we model them with the Mamba-2 SSD block (same state
size) since SSD is this framework's SSM substrate — the state/compute scaling
that matters for the roofline is identical.
"""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
        d_ff=14336, vocab=65536, attn_every=8,
        moe=MoEConfig(n_experts=16, top_k=2, d_ff=14336, every=2),
        ssm=SSMConfig(d_state=16, head_dim=64, n_groups=1, conv_width=4,
                      expand=2),
        dtype=dtype,
    )


def smoke_config(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256, attn_every=8,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=128, every=2,
                      capacity_factor=8.0),
        ssm=SSMConfig(d_state=16, head_dim=16, n_groups=1, conv_width=4,
                      expand=2, chunk=32),
        dtype=dtype, remat=False,
    )
