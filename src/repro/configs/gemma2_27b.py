"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16, d_head=128)
d_ff=36864 vocab=256000; local(4096-window)/global alternation + attention
and final logit softcaps, tied embeddings [arXiv:2408.00118]."""
from repro.models.config import ModelConfig


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b", family="dense",
        n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_head=128,
        d_ff=36864, vocab=256_000, rope_theta=10_000.0,
        sliding_window=4096, global_every=2,
        attn_softcap=50.0, final_softcap=30.0,
        tie_embeddings=True, dtype=dtype,
    )


def smoke_config(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256, sliding_window=16, global_every=2,
        attn_softcap=50.0, final_softcap=30.0,
        tie_embeddings=True, dtype=dtype, remat=False,
    )
