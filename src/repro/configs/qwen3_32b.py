"""qwen3-32b [dense] — 64L d_model=5120 64H (GQA kv=8, d_head=128)
d_ff=25600 vocab=151936, qk-norm [hf:Qwen/Qwen3-32B]."""
from repro.models.config import ModelConfig


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=25600, vocab=151936, rope_theta=1_000_000.0, qk_norm=True,
        dtype=dtype,
    )


def smoke_config(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256, qk_norm=True, dtype=dtype, remat=False,
    )
