"""mamba2-130m [ssm] — 24L d_model=768, attention-free, vocab=50280,
ssm_state=128, d_inner=1536 (24 SSD heads of dim 64) [arXiv:2405.21060]."""
from repro.models.config import ModelConfig, SSMConfig


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, n_heads=12, n_kv_heads=12,  # unused (no attn)
        d_ff=0, vocab=50280, attn_every=0,
        ssm=SSMConfig(d_state=128, head_dim=64, n_groups=1, conv_width=4,
                      expand=2),
        tie_embeddings=True, dtype=dtype,
    )


def smoke_config(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=256, attn_every=0,
        ssm=SSMConfig(d_state=32, head_dim=16, n_groups=1, conv_width=4,
                      expand=2, chunk=32),
        tie_embeddings=True, dtype=dtype, remat=False,
    )
