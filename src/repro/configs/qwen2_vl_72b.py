"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8, d_head=128)
d_ff=29568 vocab=152064; M-RoPE (t/h/w sections 16/24/24), dynamic-resolution
vision frontend STUBBED: input_specs() provides 1024 patch embeddings
prepended to the text tokens [arXiv:2409.12191]."""
from repro.models.config import ModelConfig


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=29568, vocab=152064, rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),
        frontend="vision", n_frontend_tokens=1024,
        dtype=dtype,
    )


def smoke_config(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256, mrope_sections=(2, 3, 3),
        frontend="vision", n_frontend_tokens=8,
        dtype=dtype, remat=False,
    )
