"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) expert
d_ff=512 vocab=49155; 32 experts top-8, tied embeddings
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.models.config import ModelConfig, MoEConfig


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_head=64,
        d_ff=512, vocab=49155,
        moe=MoEConfig(n_experts=32, top_k=8, d_ff=512, every=1),
        tie_embeddings=True, dtype=dtype,
    )


def smoke_config(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=64, vocab=256,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=64, every=1,
                      capacity_factor=8.0),
        tie_embeddings=True, dtype=dtype, remat=False,
    )
