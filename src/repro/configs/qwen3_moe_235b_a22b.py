"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4, d_head=128)
expert d_ff=1536 vocab=151936; 128 experts top-8, qk-norm
[hf:Qwen/Qwen3-235B-A22B]."""
from repro.models.config import ModelConfig, MoEConfig


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
        d_ff=1536, vocab=151936, rope_theta=1_000_000.0, qk_norm=True,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff=1536, every=1),
        dtype=dtype,
    )


def smoke_config(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256, qk_norm=True,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=64, every=1,
                      capacity_factor=8.0),
        dtype=dtype, remat=False,
    )
