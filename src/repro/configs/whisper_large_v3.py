"""whisper-large-v3 [audio enc-dec] — 32+32L d_model=1280 20H (MHA kv=20)
d_ff=5120 vocab=51866; conv frontend STUBBED: input_specs() provides
precomputed frame embeddings [B, 1500, 1280] [arXiv:2212.04356].

Backbone notes: learned absolute positions (pos_embed="learned"); the
decoder position table is sized to the assigned decode shapes (32k), far
beyond whisper's native 448 — the assignment exercises the backbone, not
the ASR task. long_500k is skipped (quadratic attention).
"""
from repro.models.config import EncoderConfig, ModelConfig


def config(dtype: str = "bfloat16") -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="encdec",
        n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
        d_ff=5120, vocab=51866,
        encoder=EncoderConfig(n_layers=32, n_ctx=1500),
        pos_embed="learned", max_position=32_768,
        tie_embeddings=True, dtype=dtype,
    )


def smoke_config(dtype: str = "float32") -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="encdec",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        encoder=EncoderConfig(n_layers=2, n_ctx=32),
        pos_embed="learned", max_position=128,
        tie_embeddings=True, dtype=dtype, remat=False,
    )
