"""KV-cache capacity planning: ModelConfig + HBM budget -> ``kv_blocks``.

The simulator treats KV-cache blocks as an abstract per-host capacity
dimension (``Hosts.kv_blocks``, DESIGN.md §14).  This module grounds that
number in a real checkpoint: a transformer's KV cache costs
``2 * n_attn_layers * n_kv_heads * d_head * bytes_per_elem`` bytes per
token (K and V), attention-free pattern positions (SSM mixers) cost
nothing, and a paged allocator hands the budget out in blocks of
``block_tokens`` tokens.  ``serving_scenario(kv_blocks=...)`` fed from
``kv_blocks_per_device`` turns "will a fleet of H100 replicas hold this
model's tail latency at rate r" into one campaign sweep.
"""
from __future__ import annotations

from repro.models.config import ModelConfig

_DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4}


def n_attn_layers(cfg: ModelConfig) -> int:
    """Layers that actually keep a KV cache (attention mixers; SSM pattern
    positions hold constant-size state instead and are excluded)."""
    period = cfg.period
    per_period = sum(
        1 for p in range(period) if cfg.mixer_kind(p) == "attn"
    )
    return cfg.n_periods * per_period


def kv_bytes_per_token(cfg: ModelConfig, *, cache_dtype: str | None = None) -> int:
    """Bytes of KV cache one token occupies across the whole stack."""
    elem = _DTYPE_BYTES[cache_dtype or cfg.dtype]
    return 2 * n_attn_layers(cfg) * cfg.n_kv_heads * cfg.d_head * elem


def kv_blocks_per_device(
    cfg: ModelConfig,
    hbm_bytes: float,
    *,
    block_tokens: int = 16,
    weight_bytes: float | None = None,
    reserve_frac: float = 0.1,
    cache_dtype: str | None = None,
) -> int:
    """Whole KV blocks a device can serve after weights and a working
    reserve.  ``weight_bytes`` defaults to the checkpoint's parameter count
    at the compute dtype; ``reserve_frac`` of HBM is held back for
    activations/fragmentation (vLLM's gpu_memory_utilization, inverted)."""
    if weight_bytes is None:
        weight_bytes = cfg.param_count() * _DTYPE_BYTES[cfg.dtype]
    budget = hbm_bytes * (1.0 - reserve_frac) - weight_bytes
    if budget <= 0:
        return 0
    per_block = kv_bytes_per_token(cfg, cache_dtype=cache_dtype) * block_tokens
    return int(budget // per_block)
