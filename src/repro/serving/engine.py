"""Continuous-batching serving engine over the unified Model API.

Slots are rows of a shared batched KV cache; each engine step decodes one
token for every occupied slot (inactive slots are masked out of the
scheduler's view — their compute is wasted but the batch shape is static,
which is what a TPU serving binary wants).  Prefill runs one request at a
time into its slot (prefill batching is a beyond-paper extension noted in
EXPERIMENTS.md).

The engine delegates admission/preemption to serving.scheduler (the CloudSim
policy), and can re-run ``choose_policy`` every ``replan_every`` steps —
live predictive scheduling, the paper's simulator used in production.
"""
from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.serving.scheduler import Request, SlotScheduler, choose_policy


class ServingEngine:
    def __init__(
        self,
        model: Model,
        params: Any,
        n_slots: int,
        max_len: int,
        policy: int = 0,
        quantum: int = 32,
        replan_every: int = 0,       # 0 = fixed policy
        eos_token: int = -1,
    ):
        self.model, self.params = model, params
        self.n_slots, self.max_len = n_slots, max_len
        self.sched = SlotScheduler(n_slots, policy, quantum)
        self.replan_every = replan_every
        self.eos = eos_token
        self.caches = model.init_caches(n_slots, max_len)
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self.requests: list[Request] = []
        self.steps = 0
        self.tokens_per_sec = 100.0   # running estimate, feeds the simulator
        self._decode = jax.jit(model.decode_step)
        # single-slot prefill jitted per prompt-length bucket
        self._prefill_cache: dict[int, Any] = {}

    # ------------------------------------------------------------- intake
    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> Request:
        r = Request(
            rid=len(self.requests),
            arrival=self.steps,
            prompt_len=len(prompt),
            max_new_tokens=max_new_tokens,
        )
        r.prompt = np.asarray(prompt, np.int32)      # type: ignore[attr-defined]
        self.requests.append(r)
        return r

    # ------------------------------------------------------------- internals
    def _prefill_into_slot(self, r: Request) -> None:
        prompt = jnp.asarray(r.prompt)[None]         # [1, P]
        logits, cache = self.model.prefill(
            self.params, {"tokens": prompt}, self.max_len
        )
        slot = r.slot
        # write the single-request cache into the batched slot row
        self.caches = jax.tree.map(
            lambda big, one: big.at[:, slot : slot + 1].set(one),
            self.caches, cache,
        )
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        self.tokens = self.tokens.at[slot, 0].set(tok[0])
        self.pos = self.pos.at[slot].set(r.prompt_len)
        r.generated = 1

    # ------------------------------------------------------------- main loop
    def step(self) -> dict:
        """One engine iteration: (re)plan, admit+prefill, decode one batched token."""
        if self.replan_every and self.steps % self.replan_every == 0:
            pol, _ = choose_policy(
                self.requests, self.n_slots, self.tokens_per_sec
            )
            self.sched.policy = pol

        for r in self.sched.assign(self.requests):
            self._prefill_into_slot(r)

        t0 = time.perf_counter()
        logits, self.caches = self._decode(
            self.params, self.caches, self.tokens, self.pos
        )
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(nxt)
        dt = max(time.perf_counter() - t0, 1e-6)

        active = [r for r in self.requests if r.slot >= 0 and not r.done]
        self.tokens_per_sec = 0.9 * self.tokens_per_sec + 0.1 * (
            max(len(active), 1) / dt
        )
        self.tokens = nxt[:, None]
        self.pos = self.pos + 1
        self.steps += 1

        finished = []
        for r in active:
            r.generated += 1
            tok = int(nxt[r.slot])
            if r.generated >= r.max_new_tokens or tok == self.eos:
                r.done = True
                r.finish_time = self.steps
                r.slot = -1
                finished.append(r)
        return {
            "step": self.steps,
            "active": len(active),
            "finished": [r.rid for r in finished],
            "tokens_per_sec": self.tokens_per_sec,
        }

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        while any(not r.done for r in self.requests) and self.steps < max_steps:
            self.step()
        return self.requests
