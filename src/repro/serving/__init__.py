"""repro.serving — continuous batching driven by the CloudSim policy engine."""
from repro.serving.capacity import (
    kv_blocks_per_device,
    kv_bytes_per_token,
    n_attn_layers,
)
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import Request, SlotScheduler, choose_policy, queue_scenario

__all__ = [
    "ServingEngine",
    "Request",
    "SlotScheduler",
    "choose_policy",
    "queue_scenario",
    "kv_blocks_per_device",
    "kv_bytes_per_token",
    "n_attn_layers",
]
