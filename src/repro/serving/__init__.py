"""repro.serving — continuous batching driven by the CloudSim policy engine."""
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import Request, SlotScheduler, choose_policy, queue_scenario

__all__ = ["ServingEngine", "Request", "SlotScheduler", "choose_policy", "queue_scenario"]
