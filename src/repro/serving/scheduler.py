"""CloudSim-driven continuous-batching scheduler (the paper as control plane).

Mapping (DESIGN.md §2): inference **requests = Cloudlets**, **KV-cache slots
= VMs**, **device group = Host**.  The two CloudSim policies become admission
disciplines:

  * space-shared  — a request owns its slot until completion; excess requests
    queue (Figure 4a semantics at the slot level).
  * time-shared   — more requests than slots are multiplexed round-robin with
    a token quantum (Figure 4d semantics; preemption swaps the slot's cache).

The *predictive* use — the paper's stated purpose, "tune the performance
bottlenecks before deploying" — is operational here: ``choose_policy`` builds
a CloudSim scenario from the live queue (request length -> cloudlet MI via
the measured per-token cost) and simulates BOTH policies, picking the lower
expected mean turnaround / makespan.  The simulator and the serving engine
share one policy object, so what is simulated is what runs.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import (
    SPACE_SHARED,
    TIME_SHARED,
    Scenario,
    scenarios as builders,
    simulate,
)


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float          # engine step time (s)
    prompt_len: int
    max_new_tokens: int
    generated: int = 0
    slot: int = -1          # -1 = waiting
    done: bool = False
    finish_time: float = -1.0


def queue_scenario(
    requests: list[Request],
    n_slots: int,
    tokens_per_sec: float,
    vm_policy: int,
) -> Scenario:
    """Live queue -> CloudSim scenario: slots are VMs on one host whose core
    count is the slot count; each pending/running request is a cloudlet whose
    remaining tokens convert to MI at 1 token = 1 MI, host speed =
    measured decode throughput (MI/s == tokens/s)."""
    live = [r for r in requests if not r.done]
    n = max(len(live), 1)
    hosts = builders.uniform_hosts(
        1, 1, cores=n_slots, mips=tokens_per_sec, ram_mb=1e9, bw_mbps=1e9
    )
    vms = builders.uniform_vms(
        1, cores=n_slots, mips=tokens_per_sec, ram_mb=1.0, bw_mbps=1.0
    )
    remaining = np.array(
        [max(r.max_new_tokens - r.generated, 1) for r in live] or [1],
        np.float32,
    )
    submit = np.zeros(n, np.float32)
    cls = builders.make_cloudlets(
        np.zeros(n, np.int32), remaining, submit,
        input_mb=0.0, output_mb=0.0,
    )
    pol = builders.make_policy(
        host_policy=SPACE_SHARED, vm_policy=vm_policy, horizon=1e7
    )
    return Scenario(hosts=hosts, vms=vms, cloudlets=cls,
                    market=builders.uniform_market(1), policy=pol)


def choose_policy(
    requests: list[Request], n_slots: int, tokens_per_sec: float
) -> tuple[int, dict]:
    """Simulate the live queue under both policies; pick the better one.

    Returns (policy, {"space": metrics, "time": metrics}).  Preference:
    lower mean turnaround, tie-broken by makespan — the paper's Table-1
    metrics used as an online objective.
    """
    live = [r for r in requests if not r.done]
    if not live:
        return SPACE_SHARED, {}
    out = {}
    for name, pol in (("space", SPACE_SHARED), ("time", TIME_SHARED)):
        scn = queue_scenario(requests, n_slots, tokens_per_sec, pol)
        res = jax.jit(simulate)(scn)
        out[name] = {
            "mean_tat": float(res.mean_turnaround),
            "makespan": float(res.makespan),
        }
    better = (
        SPACE_SHARED
        if out["space"]["mean_tat"] <= out["time"]["mean_tat"]
        else TIME_SHARED
    )
    return better, out


class SlotScheduler:
    """Slot assignment under a CloudSim policy (host-side, O(requests))."""

    def __init__(self, n_slots: int, policy: int = SPACE_SHARED,
                 quantum: int = 32):
        self.n_slots = n_slots
        self.policy = policy
        self.quantum = quantum          # decode steps between RR rotations
        self._rr_counter = 0

    def assign(self, requests: list[Request]) -> list[Request]:
        """Mutates slot assignments; returns requests newly (re)admitted."""
        free = set(range(self.n_slots)) - {
            r.slot for r in requests if r.slot >= 0 and not r.done
        }
        waiting = [r for r in requests if not r.done and r.slot < 0]
        admitted: list[Request] = []

        if self.policy == TIME_SHARED and waiting:
            self._rr_counter += 1
            if self._rr_counter >= self.quantum:
                self._rr_counter = 0
                running = sorted(
                    (r for r in requests if r.slot >= 0 and not r.done),
                    key=lambda r: r.generated, reverse=True,
                )
                # preempt the most-served request per rotation (swap out)
                if running:
                    victim = running[0]
                    free.add(victim.slot)
                    victim.slot = -1
                    waiting = [r for r in requests if not r.done and r.slot < 0]

        for r in sorted(waiting, key=lambda r: r.arrival):   # FCFS
            if not free:
                break
            r.slot = free.pop()
            admitted.append(r)
        return admitted
