"""Benchmark driver: one section per paper table/figure + beyond-paper rows.

    PYTHONPATH=src python -m benchmarks.run

Emits ``name,metric,value[,paper_value]`` CSV-ish lines so EXPERIMENTS.md
tables regenerate mechanically, aggregates every per-benchmark JSON into one
``BENCH_report.json``, and exits nonzero if any section raised — a crashed
benchmark used to leave its stale JSON behind for CI to upload as if fresh;
now the stale file is deleted up front, the failure is recorded in the
aggregate report, and the build fails.  The dry-run/roofline sweep is
separate (repro.launch.dryrun) because it needs the 512-device XLA flag.
"""
from __future__ import annotations

import json
import os
import sys
import time
import traceback

# (section title, module under benchmarks/, JSON artifact or None)
SECTIONS = (
    ("Figure 7/8: instantiation time & memory (100 -> 100k hosts)",
     "fig7_8_instantiation", None),
    ("Figure 9/10: space- vs time-shared task execution",
     "fig9_10_scheduling", None),
    ("Table 1: federated vs non-federated clouds",
     "table1_federation", None),
    ("Campaign throughput (beyond paper: vmapped simulations)",
     "campaign_throughput", None),
    ("Engine advance-sweep: jnp vs Pallas (-> BENCH_engine.json)",
     "engine_sweep", "BENCH_engine.json"),
    ("Dynamic workloads + auto-scaling (-> BENCH_autoscale.json)",
     "autoscale_workload", "BENCH_autoscale.json"),
    ("Live VM migration across federated DCs (-> BENCH_migration.json)",
     "live_migration", "BENCH_migration.json"),
    ("Host failures + SLA reliability (-> BENCH_reliability.json)",
     "reliability", "BENCH_reliability.json"),
    ("Serving scheduler (beyond paper: CloudSim-driven batching)",
     "serving_sched", None),
    ("Energy + topology (the paper's future work, implemented)",
     "energy_topology", None),
)

REPORT_PATH = "BENCH_report.json"


def main() -> int:
    t_all = time.time()
    report: dict = {"sections": {}, "ok": True}

    # A benchmark that crashes must not leave last run's JSON lying around
    # looking fresh.
    for _, _, artifact in SECTIONS:
        if artifact and os.path.exists(artifact):
            os.remove(artifact)

    for title, mod_name, artifact in SECTIONS:
        print(f"\n# --- {title} ---")
        entry: dict = {"title": title}
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
            mod.main()
            entry["status"] = "ok"
        except Exception:
            traceback.print_exc()
            entry["status"] = "error"
            entry["error"] = traceback.format_exc(limit=20)
            report["ok"] = False
        entry["wall_s"] = round(time.time() - t0, 3)
        if artifact:
            try:
                with open(artifact) as f:
                    entry["artifact"] = {"path": artifact, "data": json.load(f)}
            except (OSError, json.JSONDecodeError) as e:
                # missing or truncated artifact: record, don't crash the
                # aggregator — that is the failure mode this driver exists
                # to surface
                if entry["status"] == "ok":
                    entry["status"] = "error"
                    entry["error"] = f"artifact {artifact} unreadable: {e}"
                    report["ok"] = False
        report["sections"][mod_name] = entry

    report["total_wall_s"] = round(time.time() - t_all, 1)
    with open(REPORT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(f"\n# wrote {REPORT_PATH}")
    print(f"# total wall time: {report['total_wall_s']:.1f}s")
    failed = [m for m, e in report["sections"].items()
              if e["status"] != "ok"]
    if failed:
        print(f"# FAILED sections: {', '.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
