"""Benchmark driver: one section per paper table/figure + beyond-paper rows.

    PYTHONPATH=src python -m benchmarks.run

Emits ``name,metric,value[,paper_value]`` CSV-ish lines so EXPERIMENTS.md
tables regenerate mechanically.  The dry-run/roofline sweep is separate
(repro.launch.dryrun) because it needs the 512-device XLA flag.
"""
from __future__ import annotations

import time


def _section(title: str):
    print(f"\n# --- {title} ---")


def main() -> None:
    t_all = time.time()

    _section("Figure 7/8: instantiation time & memory (100 -> 100k hosts)")
    from benchmarks import fig7_8_instantiation

    fig7_8_instantiation.main()

    _section("Figure 9/10: space- vs time-shared task execution")
    from benchmarks import fig9_10_scheduling

    fig9_10_scheduling.main()

    _section("Table 1: federated vs non-federated clouds")
    from benchmarks import table1_federation

    table1_federation.main()

    _section("Campaign throughput (beyond paper: vmapped simulations)")
    from benchmarks import campaign_throughput

    campaign_throughput.main()

    _section("Engine advance-sweep: jnp vs Pallas (-> BENCH_engine.json)")
    from benchmarks import engine_sweep

    engine_sweep.main()

    _section("Serving scheduler (beyond paper: CloudSim-driven batching)")
    from benchmarks import serving_sched

    serving_sched.main()

    _section("Energy + topology (the paper's future work, implemented)")
    from benchmarks import energy_topology

    energy_topology.main()

    print(f"\n# total wall time: {time.time() - t_all:.1f}s")


if __name__ == "__main__":
    main()
