"""Host failures + SLA-driven reliability (DESIGN.md §9).

Beyond-paper rows for the abstract's "policies for migration of VMs *for
reliability*" claim: the deterministic evacuation demo — proactive
pre-failure drain vs restart-from-zero, same compiled program — and a
vmapped MTBF x (evacuation, ckpt-interval) campaign over seeded outage
schedules, reported as throughput.  The jnp-path number
``reliability_sweep.jnp.scenarios_per_s`` is gated by
``benchmarks/check_regression.py`` against ``BENCH_baseline.json``.

    PYTHONPATH=src python -m benchmarks.reliability

Writes ``BENCH_reliability.json``.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    INF,
    broadcast_campaign,
    run_campaign,
    scenarios,
    simulate,
    workload,
)

OUT_PATH = "BENCH_reliability.json"


def bench_evacuation_demo() -> dict:
    """Evacuate-ahead-of-failure vs restart-from-zero (the acceptance demo):
    fewer SLA violations and less downtime at the same energy order of
    magnitude, in one compiled program (the policy knobs are traced)."""
    fn = jax.jit(simulate)
    rows = {}
    for name, kw in (
        ("evacuated", dict(evacuation=True, ckpt_interval=100_000.0)),
        ("restart", dict(evacuation=False, ckpt_interval=float(INF))),
    ):
        res = fn(scenarios.evacuation_scenario(**kw))
        jax.block_until_ready(res)
        rows[name] = {
            "n_finished": int(res.n_finished),
            "sla_violations": int(res.sla_violations),
            "downtime_s": float(res.downtime),
            "n_evacuations": int(res.n_evacuations),
            "makespan_s": float(res.makespan),
            "energy_j": float(np.sum(np.array(res.energy_j))),
        }
    rows["evac_beats_restart"] = bool(
        rows["evacuated"]["sla_violations"] < rows["restart"]["sla_violations"]
        and rows["evacuated"]["downtime_s"] < rows["restart"]["downtime_s"]
    )
    rows["energy_ratio"] = (
        rows["evacuated"]["energy_j"] / max(rows["restart"]["energy_j"], 1e-9)
    )
    return rows


def _grid(template, n_mtbf: int, n_pol: int):
    """K = n_mtbf x n_pol campaign: seeded outage schedules crossed with
    (evacuation, ckpt_interval) policy rows; the last MTBF level is INF —
    the never-failing control rides inside the same compiled program."""
    k = n_mtbf * n_pol
    levels = jnp.concatenate([
        jnp.logspace(2.5, 3.5, n_mtbf - 1, dtype=jnp.float32),
        jnp.asarray([float(INF)], jnp.float32),
    ])
    mtbfs = jnp.repeat(levels, n_pol)
    evac = jnp.tile(
        jnp.asarray([True, False] * (n_pol // 2) + [True] * (n_pol % 2)),
        n_mtbf)
    ckpt = jnp.tile(
        jnp.linspace(20_000.0, 80_000.0, n_pol, dtype=jnp.float32), n_mtbf)
    keys = jax.random.split(jax.random.PRNGKey(11), k)
    outs = jax.vmap(
        lambda key, m: workload.host_outages(key, 2, 3, 2, m, 400.0)
    )(keys, mtbfs)
    pols = jax.vmap(
        lambda e, c: template.policy.replace(evacuation=e, ckpt_interval=c)
    )(evac, ckpt)
    return broadcast_campaign(template, k, outages=outs, policy=pols), k


def bench_reliability_sweep(n_mtbf: int = 4, n_pol: int = 4,
                            n_rep: int = 3) -> dict:
    template = scenarios.reliability_scenario(jax.random.PRNGKey(0))
    batched, k = _grid(template, n_mtbf, n_pol)

    res = run_campaign(batched)                      # compile + warm
    jax.block_until_ready(res)
    t0 = time.perf_counter()
    for _ in range(n_rep):
        res = run_campaign(batched)
        jax.block_until_ready(res)
    wall = (time.perf_counter() - t0) / n_rep

    # acceptance: the vmapped grid row-matches a per-scenario Python loop
    fn = jax.jit(simulate)
    match = True
    for i in range(k):
        row = template.replace(
            policy=jax.tree.map(lambda x: x[i], batched.policy),
            outages=jax.tree.map(lambda x: x[i], batched.outages))
        r = fn(row)
        for f in ("n_finished", "sla_violations", "downtime",
                  "n_evacuations", "makespan"):
            if not np.array_equal(np.array(getattr(res, f)[i]),
                                  np.array(getattr(r, f))):
                match = False
    n_cl = template.cloudlets.n_cloudlets
    viol = np.array(res.sla_violations)
    return {
        "jnp": {
            "grid_points": k,
            "wall_s": wall,
            "scenarios_per_s": k / wall,
        },
        "vmap_matches_loop": bool(match),
        "all_finished": bool((np.array(res.n_finished) == n_cl).all()),
        "sla_violations_min": int(viol.min()),
        "sla_violations_max": int(viol.max()),
        "total_downtime_s": float(np.sum(np.array(res.downtime))),
        "total_evacuations": int(np.sum(np.array(res.n_evacuations))),
    }


def run() -> dict:
    return {
        "backend": jax.default_backend(),
        "evacuation_demo": bench_evacuation_demo(),
        "reliability_sweep": bench_reliability_sweep(),
    }


def main() -> None:
    report = run()
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {OUT_PATH}")
    d = report["evacuation_demo"]
    print(f"reliability,evacuation_demo,"
          f"violations={d['evacuated']['sla_violations']}"
          f"/{d['restart']['sla_violations']},"
          f"downtime={d['evacuated']['downtime_s']:.1f}"
          f"/{d['restart']['downtime_s']:.1f},"
          f"beats={d['evac_beats_restart']}")
    g = report["reliability_sweep"]
    print(f"reliability,sweep,points={g['jnp']['grid_points']},"
          f"scenarios_per_s={g['jnp']['scenarios_per_s']:.3f},"
          f"vmap_matches_loop={g['vmap_matches_loop']}")


if __name__ == "__main__":
    main()
