"""Event-engine throughput: batch-major step loop vs vmap-of-simulate.

The tentpole metric of the batch-major refactor (DESIGN.md §10): one
compiled step advances a ``[B, ...]`` campaign natively, so the expensive
event phases (the sequential VM-provisioning scan, the broker dispatch
sort) run under *scalar* ``lax.cond``s on batch-global predicates and are
genuinely skipped when no live row needs them — whereas ``vmap(simulate)``
turns the same conds into ``select``s and pays every phase at every event.

    PYTHONPATH=src python -m benchmarks.event_engine

Writes ``BENCH_event_engine.json``:

* ``event_engine_single.{jnp,pallas}.events_per_s`` — one scenario through
  ``simulate`` under both advance-sweep routings.
* ``event_engine_batch.{batch_major,vmap}.batch_events_per_s`` — the same
  scenario x B=256 (staggered task lengths) through the batch-major path
  vs ``jit(vmap(simulate))``, plus their speedup and a bitwise-equality
  seat (the batch path must be a perf optimization, not a semantic fork).
* ``advance_pow2.{jnp,pallas}`` — the fused advance kernel at an exact
  power-of-two row, where interpret mode pays no padding copies; on CPU
  this is the honest kernel comparison (DESIGN.md §10 caveat), the
  c=100k row lives in BENCH_engine.json.

The benchmark scenario is deliberately provisioning-heavy (few cloudlets,
a large host table): per event the policy/bound/commit work is tens of
small ops while one provisioning pass scans V VMs over [D, H] host tables,
and only the first event has VMs to place — the regime the paper's
Figure 7/8 instantiation experiments model, and the one where batch-major
phase skipping pays.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import simulate, simulate_instrumented, stack_scenarios
from repro.core.entities import SPACE_SHARED, Scenario
from repro.core.scenarios import (
    make_cloudlets,
    make_policy,
    uniform_hosts,
    uniform_market,
    uniform_vms,
)
from repro.kernels import ops

OUT_PATH = "BENCH_event_engine.json"
BATCH = 256


def _time(fn, *args, n_rep: int = 3) -> float:
    out = fn(*args)                                # compile + warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n_rep):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n_rep


def bench_scenario(mi_scale: float = 1.0, n_vms: int = 50,
                   n_hosts: int = 8_000, n_waves: int = 8,
                   sweep_impl: str = "jnp") -> Scenario:
    """Provisioning-heavy event stream: ``n_vms`` VMs requested at t=0
    (one placement event scanning a 1 x ``n_hosts`` table), then
    ``n_waves`` single-cloudlet submission waves 100 s apart — ~2 events
    per wave, none of which has provisioning or dispatch work."""
    hosts = uniform_hosts(1, n_hosts, cores=1, mips=1000.0)
    vms = uniform_vms(n_vms, ram_mb=128.0)
    cl_vm = np.arange(n_waves) % n_vms
    submit = np.arange(n_waves) * 100.0
    cls = make_cloudlets(cl_vm, np.full(n_waves, 30_000.0 * mi_scale), submit)
    pol = make_policy(host_policy=SPACE_SHARED, vm_policy=SPACE_SHARED,
                      core_reserving=True)
    return Scenario(hosts=hosts, vms=vms, cloudlets=cls,
                    market=uniform_market(1), policy=pol,
                    sweep_impl=sweep_impl)


def bench_single(n_rep: int = 3) -> dict:
    rows = {}
    for impl in ("jnp", "pallas"):
        scn = bench_scenario(sweep_impl=impl)
        fn = jax.jit(simulate)
        wall = _time(fn, scn, n_rep=n_rep)
        res = fn(scn)
        n_events = int(res.n_events)
        rows[impl] = {
            "wall_s": wall,
            "n_events": n_events,
            "events_per_s": n_events / wall,
            "n_finished": int(res.n_finished),
        }
    return rows


def bench_batch(b: int = BATCH) -> dict:
    scn_b = stack_scenarios(
        [bench_scenario(1.0 + 0.002 * i) for i in range(b)]
    )

    # rank detection routes the stacked pytree through the batch-major loop
    run_batch = jax.jit(simulate)
    # the baseline the refactor replaces: campaign axis in an outer vmap
    run_vmap = jax.jit(jax.vmap(lambda s: simulate_instrumented(s)[0]))

    res_b = run_batch(scn_b)
    n_events = int(np.asarray(res_b.n_events).sum())
    wall_b = _time(run_batch, scn_b, n_rep=2)
    res_v = run_vmap(scn_b)
    wall_v = _time(run_vmap, scn_b, n_rep=1)

    bitwise = all(
        bool(jnp.array_equal(x, y)) for x, y in
        zip(jax.tree.leaves(res_b), jax.tree.leaves(res_v))
    )
    return {
        "batch_size": b,
        "n_events": n_events,
        "batch_major": {
            "wall_s": wall_b,
            "batch_events_per_s": n_events / wall_b,
        },
        "vmap": {
            "wall_s": wall_v,
            "batch_events_per_s": n_events / wall_v,
        },
        "speedup_batch_vs_vmap": wall_v / wall_b,
        "bitwise_equal": bitwise,
    }


def bench_advance_pow2(c: int = 1 << 17, n_rep: int = 5) -> dict:
    """The fused kernel with zero interpret-mode padding overhead."""
    rng = np.random.default_rng(0)
    rem = jnp.asarray(rng.uniform(1e3, 1e6, c).astype(np.float32))
    rate = jnp.asarray(rng.uniform(0.0, 1e3, c).astype(np.float32))
    active = rate > 1.0
    bound = jnp.asarray(1e4, jnp.float32)

    rows = {}
    for impl in ("jnp", "pallas"):
        fn = jax.jit(ops.resolve_advance(impl))
        wall = _time(fn, rem, rate, active, bound, n_rep=n_rep)
        rows[impl] = {"wall_s": wall, "cloudlets": c,
                      "cloudlets_per_s": c / wall}
    return rows


def run() -> dict:
    report = {
        "backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() != "tpu",
        "event_engine_single": bench_single(),
        "event_engine_batch": bench_batch(),
        "advance_pow2": bench_advance_pow2(),
    }
    if not report["event_engine_batch"]["bitwise_equal"]:
        raise AssertionError(
            "batch-major SimResult diverged bitwise from vmap-of-simulate"
        )
    return report


def main() -> None:
    report = run()
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {OUT_PATH}")
    for impl, row in report["event_engine_single"].items():
        print(f"event_engine_single,{impl},events_per_s={row['events_per_s']:.6g}")
    batch = report["event_engine_batch"]
    for impl in ("batch_major", "vmap"):
        print(f"event_engine_batch,{impl},"
              f"batch_events_per_s={batch[impl]['batch_events_per_s']:.6g}")
    print(f"event_engine_batch,speedup,"
          f"{batch['speedup_batch_vs_vmap']:.3g}x,"
          f"bitwise_equal={batch['bitwise_equal']}")
    for impl, row in report["advance_pow2"].items():
        print(f"advance_pow2,{impl},cloudlets_per_s={row['cloudlets_per_s']:.6g}")


if __name__ == "__main__":
    main()
