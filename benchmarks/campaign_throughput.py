"""Beyond-paper: simulation-campaign throughput (sims/s, events/s) vs vmap
width — the batched-simulation capability CloudSim never had."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import scenarios, simulate, stack_scenarios


def run(widths=(1, 8, 64, 256)) -> list[dict]:
    rows = []
    base = [scenarios.fig4_scenario(hp, vp)
            for hp in (0, 1) for vp in (0, 1)]
    run_fn = jax.jit(jax.vmap(simulate))
    for w in widths:
        scns = stack_scenarios((base * ((w + 3) // 4))[:w])
        res = run_fn(scns)                      # compile + warm
        jax.block_until_ready(res.makespan)
        t0 = time.perf_counter()
        n_rep = 5
        for _ in range(n_rep):
            res = run_fn(scns)
            jax.block_until_ready(res.makespan)
        dt = (time.perf_counter() - t0) / n_rep
        rows.append({
            "width": w,
            "wall_s": dt,
            "sims_per_s": w / dt,
            "events_per_s": float(np.sum(np.array(res.n_events))) / dt,
        })
    return rows


def main():
    print("vmap_width,wall_s,sims_per_s,events_per_s")
    for r in run():
        print(f"{r['width']},{r['wall_s']:.4f},{r['sims_per_s']:.1f},"
              f"{r['events_per_s']:.0f}")


if __name__ == "__main__":
    main()
