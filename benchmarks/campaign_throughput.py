"""Campaign throughput at production scale: streaming + sharded sweeps.

The CloudSim companion paper (arXiv:0903.2525) benchmarks large-scale
instantiation; the equivalent claim here is end-to-end *sweep* throughput —
how many complete scenario simulations per second the campaign engine
sustains when the grid is too big to materialize.  Two modes:

* ``streaming`` — a >=1e5-point fig4 campaign through
  ``run_campaign(chunk_size=..., reduce=...)``: chunked batch-major
  simulation with the histogram/argbest/count folds fused into the compiled
  chunk program, so the ``[N, ...]`` result pytree never exists
  (DESIGN.md §12).  Peak memory is one chunk + the reducer carries.
* ``sharded`` — the same streaming sweep with chunks shard_mapped over every
  available device (``data`` mesh).  On CPU CI this is a 1-device mesh, so
  the number is the shard_map-lowering overhead check, not a scaling claim;
  the 4-device bitwise test lives in tests/test_campaign.py.

Both ``scenarios_per_s`` keys are gated against BENCH_baseline.json by
``check_regression.py`` (artifact: BENCH_campaign.json).
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core import broadcast_campaign, run_campaign, scenarios
from repro.core.reducers import (
    ArgBestReducer,
    HistogramReducer,
    SumReducer,
)

ARTIFACT = "BENCH_campaign.json"

N_STREAMING = 131_072      # the >=1e5-point acceptance sweep
N_SHARDED = 65_536
CHUNK = 8_192

REDUCE = {
    "events": SumReducer("n_events"),
    "turnaround": HistogramReducer("mean_turnaround", 0.0, 8000.0, bins=64),
    "best": ArgBestReducer("mean_turnaround"),
}


def _grid(n: int):
    """n-point fig4 campaign with per-row workload scale (distinct rows,
    one compiled program)."""
    base = scenarios.fig4_scenario(0, 0)
    scale = 1.0 + 0.5 * jnp.arange(n, dtype=jnp.float32) / n
    cls = jax.vmap(
        lambda s: base.cloudlets.replace(length_mi=base.cloudlets.length_mi * s)
    )(scale)
    return broadcast_campaign(base, n, cloudlets=cls)


def _timed(fn):
    out = fn()                       # compile + warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return time.perf_counter() - t0, out


def run() -> dict:
    report: dict = {}

    batched = _grid(N_STREAMING)
    dt, out = _timed(
        lambda: run_campaign(batched, chunk_size=CHUNK, reduce=REDUCE)
    )
    assert int(out["events"]) > 0 and int(out["best"]["index"]) >= 0
    report["campaign_streaming"] = {"streaming": {
        "n_scenarios": N_STREAMING,
        "chunk_size": CHUNK,
        "wall_s": dt,
        "scenarios_per_s": N_STREAMING / dt,
        "events_per_s": int(out["events"]) / dt,
    }}

    from jax.sharding import Mesh

    devs = jax.devices()
    mesh = Mesh(devs, ("data",))
    batched_s = _grid(N_SHARDED)
    dt, out = _timed(
        lambda: run_campaign(batched_s, chunk_size=CHUNK, mesh=mesh,
                             reduce=REDUCE)
    )
    report["campaign_sharded"] = {"sharded": {
        "n_scenarios": N_SHARDED,
        "chunk_size": CHUNK,
        "n_devices": len(devs),
        "wall_s": dt,
        "scenarios_per_s": N_SHARDED / dt,
    }}
    return report


def main():
    report = run()
    s = report["campaign_streaming"]["streaming"]
    print(f"campaign_streaming,n={s['n_scenarios']},chunk={s['chunk_size']},"
          f"scenarios_per_s,{s['scenarios_per_s']:.0f}")
    d = report["campaign_sharded"]["sharded"]
    print(f"campaign_sharded,n={d['n_scenarios']},devices={d['n_devices']},"
          f"scenarios_per_s,{d['scenarios_per_s']:.0f}")
    with open(ARTIFACT, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {ARTIFACT}")


if __name__ == "__main__":
    main()
