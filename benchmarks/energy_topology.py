"""Beyond paper (= the paper's own future-work list, implemented): energy
accounting + BRITE-style topology in the federation experiment."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import scenarios, simulate
from repro.core.energy import PowerModel, Topology


def run():
    rows = []
    for fed in (False, True):
        scn = scenarios.table1_scenario(fed).replace(
            power=PowerModel.uniform(3),
            topology=Topology.uniform(3, latency_s=5.0, bw_mbps=50.0),
        )
        r = jax.jit(simulate)(scn)
        e_kwh = float(np.sum(np.array(r.energy_j))) / 3.6e6
        rows.append({
            "federation": fed,
            "mean_tat": float(r.mean_turnaround),
            "makespan": float(r.makespan),
            "energy_kwh": e_kwh,
            "kwh_per_cloudlet": e_kwh / max(int(r.n_finished), 1),
        })
    return rows


def main():
    print("federation,mean_tat_s,makespan_s,energy_kWh,kWh_per_cloudlet")
    for r in run():
        print(f"{r['federation']},{r['mean_tat']:.0f},{r['makespan']:.0f},"
              f"{r['energy_kwh']:.2f},{r['kwh_per_cloudlet']:.3f}")
    # headline: federation finishes sooner -> lower total idle energy
    rows = run()
    assert rows[1]["energy_kwh"] < rows[0]["energy_kwh"]


if __name__ == "__main__":
    main()
