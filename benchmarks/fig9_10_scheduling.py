"""Paper Figures 9 & 10: task-unit progress under space- vs time-shared
cloudlet scheduling (10k hosts / 50 VMs / 500 x 20-min tasks, groups of 50
every 10 min)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SPACE_SHARED, TIME_SHARED, scenarios, simulate, simulate_trace


def run(n_hosts=10_000, n_vms=50, n_groups=10, trace=False):
    out = {}
    for name, pol in (("space", SPACE_SHARED), ("time", TIME_SHARED)):
        scn = scenarios.fig9_10_scenario(pol, n_hosts=n_hosts, n_vms=n_vms,
                                         n_groups=n_groups)
        if trace:
            ts = jnp.asarray(np.arange(0, 13_000, 500.0, dtype=np.float32))
            res, prog = simulate_trace(scn, ts)
            out[name] = (scn, res, np.array(prog))
        else:
            res = jax.jit(simulate)(scn)
            out[name] = (scn, res, None)
    return out


def main():
    res = run()
    print("policy,group,submit_s,mean_finish_s,mean_turnaround_s")
    for name, (scn, r, _) in res.items():
        sub = np.array(scn.cloudlets.submit_t)
        fin = np.array(r.finish_t)
        for g in sorted(set(sub.tolist())):
            m = sub == g
            print(f"{name},{int(g // 600)},{g:.0f},{fin[m].mean():.0f},"
                  f"{(fin[m] - g).mean():.0f}")
    # headline checks (paper): space-shared -> every task exactly 1200 s
    space = res["space"]
    tat = np.array(space[1].finish_t) - np.array(space[0].cloudlets.submit_t)
    assert np.allclose(np.sort(tat)[:50], 1200.0, rtol=5e-3)


if __name__ == "__main__":
    main()
