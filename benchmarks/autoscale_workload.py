"""Dynamic workloads + auto-scaling: turnaround under generated load.

Beyond-paper rows for the abstract's "varying load" and "automatic scaling"
claims (DESIGN.md §7): one bursty service-routed scenario simulated with the
pool autoscaler on vs off (same compiled program — the flag is traced), plus
a vmapped arrival-rate x scale-up-threshold grid, reported as throughput.

    PYTHONPATH=src python -m benchmarks.autoscale_workload

Writes ``BENCH_autoscale.json``.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    broadcast_campaign,
    run_campaign,
    scenarios,
    simulate_instrumented,
    workload,
)

OUT_PATH = "BENCH_autoscale.json"


def bench_demo(seed: int = 0) -> dict:
    fn = jax.jit(simulate_instrumented)
    rows = {}
    for name, auto in (("autoscaled", True), ("static", False)):
        scn = scenarios.autoscale_scenario(
            jax.random.PRNGKey(seed), autoscale=auto)
        res, out = fn(scn)
        jax.block_until_ready(res)
        rows[name] = {
            "n_finished": int(res.n_finished),
            "mean_turnaround_s": float(res.mean_turnaround),
            "makespan_s": float(res.makespan),
            "n_scale_up": int(out["autoscale"]["n_scale_up"]),
            "n_scale_down": int(out["autoscale"]["n_scale_down"]),
        }
    rows["turnaround_improvement"] = 1.0 - (
        rows["autoscaled"]["mean_turnaround_s"]
        / rows["static"]["mean_turnaround_s"]
    )
    return rows


def bench_grid(n_rates: int = 8, n_threshs: int = 8, n_cloudlets: int = 48,
               n_rep: int = 3) -> dict:
    """The campaign surface: K = n_rates x n_threshs scenarios in one vmap."""
    k = n_rates * n_threshs
    template = scenarios.autoscale_scenario(jax.random.PRNGKey(0))
    rates = jnp.tile(jnp.linspace(0.05, 0.2, n_rates), n_threshs)
    ups = jnp.repeat(jnp.linspace(0.3, 1.0, n_threshs), n_rates)
    keys = jax.random.split(jax.random.PRNGKey(7), k)
    cls = jax.vmap(lambda key, r: workload.generate_cloudlets(
        key, n_cloudlets, kind="bursty", n_bursts=3, rate=r,
        off_gap_mean=800.0, median_mi=60_000.0, sigma_mi=0.3, n_vms=None,
    ))(keys, rates)
    pol = jax.vmap(
        lambda u: template.policy.replace(scale_up_thresh=u))(ups)
    batched = broadcast_campaign(template, k, cloudlets=cls, policy=pol)

    res = run_campaign(batched)                      # compile + warm
    jax.block_until_ready(res)
    t0 = time.perf_counter()
    for _ in range(n_rep):
        res = run_campaign(batched)
        jax.block_until_ready(res)
    wall = (time.perf_counter() - t0) / n_rep
    tat = np.array(res.mean_turnaround)
    return {
        "grid_points": k,
        "wall_s": wall,
        "scenarios_per_s": k / wall,
        "all_finished": bool((np.array(res.n_finished) == n_cloudlets).all()),
        "mean_turnaround_min_s": float(tat.min()),
        "mean_turnaround_max_s": float(tat.max()),
    }


def run() -> dict:
    return {
        "backend": jax.default_backend(),
        "demo_bursty": bench_demo(),
        "grid_rate_x_thresh": bench_grid(),
    }


def main() -> None:
    report = run()
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {OUT_PATH}")
    d = report["demo_bursty"]
    print(f"autoscale,demo,improvement={d['turnaround_improvement']:.3f},"
          f"up={d['autoscaled']['n_scale_up']}")
    g = report["grid_rate_x_thresh"]
    print(f"autoscale,grid,points={g['grid_points']},"
          f"scenarios_per_s={g['scenarios_per_s']:.3f}")


if __name__ == "__main__":
    main()
