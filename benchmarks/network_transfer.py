"""Contention-aware network transfers (DESIGN.md §13).

Beyond-paper rows for the abstract's network-topology future work made
operational: a staging-heavy scenario (every cloudlet's input data moves
over the inter-DC link ledger under fair sharing) timed through the single
event loop and through a batch-major campaign sweeping the
``locality_dispatch`` broker knob inside one compiled program.  The gated
numbers are ``network_transfer_single.jnp.transfers_per_s`` and
``network_transfer_batch.batch_major.transfers_per_s``
(``benchmarks/check_regression.py`` vs ``BENCH_baseline.json``).

    PYTHONPATH=src python -m benchmarks.network_transfer

Writes ``BENCH_network.json``.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.core import broadcast_campaign, run_campaign, scenarios, simulate

OUT_PATH = "BENCH_network.json"


def _staging(n_cloudlets: int, locality: bool = False):
    return scenarios.staging_scenario(
        n_cloudlets=n_cloudlets, vms_per_dc=4, wave=16,
        locality_dispatch=locality)


def bench_single(n_cloudlets: int = 192, n_rep: int = 5) -> dict:
    """One staging-heavy scenario through the event loop: every cloudlet
    stages input over the link ledger, so events/transfers per second price
    the settle/open/re-time machinery itself."""
    fn = jax.jit(simulate)
    out = {}
    for name, locality in (("jnp", False), ("locality", True)):
        scn = _staging(n_cloudlets, locality)
        res = fn(scn)                                 # compile + warm
        jax.block_until_ready(res)
        t0 = time.perf_counter()
        for _ in range(n_rep):
            res = fn(scn)
            jax.block_until_ready(res)
        wall = (time.perf_counter() - t0) / n_rep
        assert int(res.n_finished) == n_cloudlets
        out[name] = {
            "n_transfers": n_cloudlets,
            "n_events": int(res.n_events),
            "wall_s": wall,
            "transfers_per_s": n_cloudlets / wall,
            "events_per_s": int(res.n_events) / wall,
            "makespan_s": float(res.makespan),
        }
    return out


def bench_batch(n_cloudlets: int = 96, batch: int = 32,
                n_rep: int = 3) -> dict:
    """The campaign surface: B scenario rows alternating the traced
    ``locality_dispatch`` knob through the batch-major step loop."""
    template = _staging(n_cloudlets)
    loc = (np.arange(batch) % 2).astype(bool)
    pol = jax.vmap(
        lambda on: template.policy.replace(locality_dispatch=on)
    )(loc)
    batched = broadcast_campaign(template, batch, policy=pol)

    res = run_campaign(batched)                       # compile + warm
    jax.block_until_ready(res)
    t0 = time.perf_counter()
    for _ in range(n_rep):
        res = run_campaign(batched)
        jax.block_until_ready(res)
    wall = (time.perf_counter() - t0) / n_rep
    fin = np.array(res.n_finished)
    mk = np.array(res.makespan)
    return {
        "batch_major": {
            "batch": batch,
            "n_transfers": batch * n_cloudlets,
            "wall_s": wall,
            "transfers_per_s": batch * n_cloudlets / wall,
        },
        "all_finished": bool((fin == n_cloudlets).all()),
        "makespan_rank_s": float(mk[~loc].mean()),
        "makespan_locality_s": float(mk[loc].mean()),
    }


def run() -> dict:
    return {
        "backend": jax.default_backend(),
        "network_transfer_single": bench_single(),
        "network_transfer_batch": bench_batch(),
    }


def main() -> None:
    report = run()
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {OUT_PATH}")
    s = report["network_transfer_single"]
    print(f"network,single,transfers_per_s={s['jnp']['transfers_per_s']:.1f},"
          f"events_per_s={s['jnp']['events_per_s']:.1f}")
    print(f"network,locality,makespan_rank={s['jnp']['makespan_s']:.1f},"
          f"makespan_locality={s['locality']['makespan_s']:.1f}")
    b = report["network_transfer_batch"]
    print(f"network,batch,B={b['batch_major']['batch']},"
          f"transfers_per_s={b['batch_major']['transfers_per_s']:.1f}")


if __name__ == "__main__":
    main()
