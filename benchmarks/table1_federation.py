"""Paper Table 1: federated vs non-federated turnaround/makespan."""
from __future__ import annotations

import jax

from repro.core import scenarios, simulate

PAPER = {
    "with": {"mean_tat": 2221.13, "makespan": 6613.1},
    "without": {"mean_tat": 4700.1, "makespan": 8405.0},
}


def run() -> dict:
    out = {}
    for fed, key in ((True, "with"), (False, "without")):
        r = jax.jit(simulate)(scenarios.table1_scenario(fed))
        out[key] = {
            "mean_tat": float(r.mean_turnaround),
            "makespan": float(r.makespan),
            "migrations": int(r.n_migrations),
            "total_cost": float(r.total_cost),
        }
    return out


def main():
    out = run()
    print("case,mean_tat_s,makespan_s,migrations,paper_tat,paper_makespan")
    for key in ("with", "without"):
        o, p = out[key], PAPER[key]
        print(f"{key},{o['mean_tat']:.1f},{o['makespan']:.1f},"
              f"{o['migrations']},{p['mean_tat']},{p['makespan']}")
    tat_cut = 1 - out["with"]["mean_tat"] / out["without"]["mean_tat"]
    mk_cut = 1 - out["with"]["makespan"] / out["without"]["makespan"]
    paper_tat_cut = 1 - PAPER["with"]["mean_tat"] / PAPER["without"]["mean_tat"]
    paper_mk_cut = 1 - PAPER["with"]["makespan"] / PAPER["without"]["makespan"]
    print(f"reduction,mean_tat,{100 * tat_cut:.1f}%,paper,"
          f"{100 * paper_tat_cut:.1f}%")
    print(f"reduction,makespan,{100 * mk_cut:.1f}%,paper,"
          f"{100 * paper_mk_cut:.1f}%")


if __name__ == "__main__":
    main()
