"""Bench-regression gate: fail CI when engine throughput drops vs baseline.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --baseline BENCH_baseline.json \
        --fresh BENCH_engine.json BENCH_event_engine.json \
                BENCH_migration.json BENCH_reliability.json \
                BENCH_campaign.json BENCH_network.json \
                BENCH_serving.json

Merges the fresh reports (top-level sections are disjoint by construction:
``benchmarks/engine_sweep.py``, ``benchmarks/event_engine.py``,
``benchmarks/live_migration.py`` and ``benchmarks/reliability.py`` each own
their sections) and compares the *jnp*-path throughput metrics against the
committed ``BENCH_baseline.json`` (refresh it only via
``python -m benchmarks.run --refresh-baseline`` so every gated section
updates together — see the baseline's ``_note`` key):

* ``advance_sweep_kernel.jnp.cloudlets_per_s`` — raw fused-sweep throughput
* ``engine_fig9_10.jnp.events_per_s``          — full-engine event rate
* ``event_engine_single.jnp.events_per_s``     — provisioning-heavy event
                                                 stream, one scenario
* ``event_engine_batch.batch_major.batch_events_per_s`` — B=256 campaign
                                                 through the batch-major
                                                 step loop (DESIGN.md §10)
* ``migration_sweep.jnp.scenarios_per_s``      — vmapped live-migration
                                                 threshold-grid campaign
* ``reliability_sweep.jnp.scenarios_per_s``    — vmapped host-failure MTBF x
                                                 policy campaign (the
                                                 revocation/failure path)
* ``campaign_streaming.streaming.scenarios_per_s`` — >=1e5-point streaming
                                                 sweep with fused reducer
                                                 folds (DESIGN.md §12)
* ``campaign_sharded.sharded.scenarios_per_s`` — the same sweep through the
                                                 shard_map chunk runner
                                                 (1-device mesh on CPU CI)
* ``network_transfer_single.jnp.transfers_per_s`` — staging-heavy fair-share
                                                 link-ledger event loop
                                                 (DESIGN.md §13)
* ``network_transfer_batch.batch_major.transfers_per_s`` — the same subject
                                                 as a B=32 locality-knob
                                                 campaign (batch-major)
* ``serving_single.jnp.serving_requests_per_s`` — KV-cache-bound continuous
                                                 batching through the event
                                                 loop (DESIGN.md §14)
* ``serving_batch.batch_major.serving_requests_per_s`` — the B=32 rate x
                                                 kv_blocks x threshold SLO
                                                 campaign (batch-major)

Only the jnp path gates: the Pallas twin runs in interpret mode on CPU CI,
so its wall time is a correctness seat, not a perf claim (DESIGN.md §4).
The tolerance is deliberately generous (default: fail below 0.5x baseline)
because shared CI runners are noisy — this catches "the hot path got 3x
slower" regressions, not 10% wiggles.  Exit status is the contract: 0 ok,
1 regression, 2 missing/contradictory inputs.  Every gated key is evaluated
before exiting — one missing benchmark section cannot mask regressions (or
further missing keys) in the other five.
"""
from __future__ import annotations

import argparse
import json
import sys

GATED = (
    ("advance_sweep_kernel", "jnp", "cloudlets_per_s"),
    ("engine_fig9_10", "jnp", "events_per_s"),
    ("event_engine_single", "jnp", "events_per_s"),
    ("event_engine_batch", "batch_major", "batch_events_per_s"),
    ("migration_sweep", "jnp", "scenarios_per_s"),
    ("reliability_sweep", "jnp", "scenarios_per_s"),
    ("campaign_streaming", "streaming", "scenarios_per_s"),
    ("campaign_sharded", "sharded", "scenarios_per_s"),
    ("network_transfer_single", "jnp", "transfers_per_s"),
    ("network_transfer_batch", "batch_major", "transfers_per_s"),
    ("serving_single", "jnp", "serving_requests_per_s"),
    ("serving_batch", "batch_major", "serving_requests_per_s"),
)


def _get(report: dict, path: tuple[str, ...], src: str) -> float:
    node = report
    for p in path:
        if not isinstance(node, dict) or p not in node:
            raise KeyError(f"{src}: missing {'/'.join(path)}")
        node = node[p]
    value = float(node)
    if value <= 0:
        raise ValueError(f"{src}: non-positive {'/'.join(path)} = {value}")
    return value


def check(baseline: dict, fresh: dict, tol: float) -> tuple[list[str], list[str]]:
    """Evaluate every gated key independently; nothing short-circuits.

    Returns ``(regressions, malformed)`` — each a list of human-readable
    failure lines covering ALL failing keys, so one broken benchmark section
    can't mask the report on the other five.
    """
    regressions, malformed = [], []
    for path in GATED:
        try:
            base = _get(baseline, path, "baseline")
            new = _get(fresh, path, "fresh")
        except (KeyError, ValueError) as e:
            malformed.append(f"MALFORMED {e}")
            continue
        ratio = new / base
        line = f"{'/'.join(path)}: {new:.6g} vs baseline {base:.6g} ({ratio:.2f}x)"
        if ratio < tol:
            regressions.append(f"REGRESSION {line} < {tol}x")
        else:
            print(f"ok {line}")
    return regressions, malformed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--fresh", nargs="+",
                    default=["BENCH_engine.json", "BENCH_event_engine.json",
                             "BENCH_migration.json",
                             "BENCH_reliability.json",
                             "BENCH_campaign.json",
                             "BENCH_network.json",
                             "BENCH_serving.json"],
                    help="fresh report(s); top-level sections are merged")
    ap.add_argument("--tol", type=float, default=0.5,
                    help="fail when fresh/baseline falls below this ratio")
    args = ap.parse_args(argv)

    reports = {"fresh": {}}
    for name, path in [("baseline", args.baseline)] + [
        ("fresh", p) for p in args.fresh
    ]:
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: cannot read {name} report {path!r}: {e}",
                  file=sys.stderr)
            return 2
        if name == "fresh":
            reports["fresh"].update(data)
        else:
            reports[name] = data

    regressions, malformed = check(
        reports["baseline"], reports["fresh"], args.tol
    )
    for line in regressions + malformed:
        print(line, file=sys.stderr)
    if malformed:
        return 2
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
