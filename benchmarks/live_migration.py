"""Live VM migration across federated datacenters (DESIGN.md §8).

Beyond-paper rows for the abstract's "federation and associated policies for
migration of VMs" claim: the energy-consolidation demo (idle-gated power
model, migration on vs off in the same compiled program) and a vmapped
consolidate-threshold x balance-threshold campaign, reported as throughput —
the jnp-path number ``migration_sweep.jnp.scenarios_per_s`` is gated by
``benchmarks/check_regression.py`` against ``BENCH_baseline.json``.

    PYTHONPATH=src python -m benchmarks.live_migration

Writes ``BENCH_migration.json``.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    broadcast_campaign,
    run_campaign,
    scenarios,
    simulate_instrumented,
)

OUT_PATH = "BENCH_migration.json"


def bench_consolidation_demo() -> dict:
    fn = jax.jit(simulate_instrumented)
    rows = {}
    for name, live in (("migrated", True), ("static", False)):
        scn = scenarios.consolidation_scenario(live_migration=live)
        res, out = fn(scn)
        jax.block_until_ready(res)
        rows[name] = {
            "n_finished": int(res.n_finished),
            "n_migrations": int(res.n_migrations),
            "n_consolidate": int(out["migration"]["n_consolidate"]),
            "energy_j": float(np.sum(np.array(res.energy_j))),
            "end_t_s": float(res.end_t),
        }
    rows["energy_saving"] = 1.0 - (
        rows["migrated"]["energy_j"] / rows["static"]["energy_j"]
    )
    return rows


def bench_threshold_sweep(n_con: int = 8, n_bal: int = 4,
                          n_rep: int = 3) -> dict:
    """The campaign surface: K = n_con x n_bal thresholds in one vmap."""
    k = n_con * n_bal
    template = scenarios.consolidation_scenario()
    cons = jnp.tile(jnp.linspace(0.0, 0.9, n_con), n_bal)
    bals = jnp.repeat(jnp.linspace(0.5, 2.0, n_bal), n_con)
    pol = jax.vmap(
        lambda c, b: template.policy.replace(
            migrate_consolidate_thresh=c, migrate_balance_thresh=b)
    )(cons, bals)
    batched = broadcast_campaign(template, k, policy=pol)

    res = run_campaign(batched)                      # compile + warm
    jax.block_until_ready(res)
    t0 = time.perf_counter()
    for _ in range(n_rep):
        res = run_campaign(batched)
        jax.block_until_ready(res)
    wall = (time.perf_counter() - t0) / n_rep
    n_mig = np.array(res.n_migrations)
    return {
        "jnp": {
            "grid_points": k,
            "wall_s": wall,
            "scenarios_per_s": k / wall,
        },
        "all_finished": bool(
            (np.array(res.n_finished)
             == template.cloudlets.n_cloudlets).all()),
        "n_migrations_min": int(n_mig.min()),
        "n_migrations_max": int(n_mig.max()),
    }


def run() -> dict:
    return {
        "backend": jax.default_backend(),
        "consolidation_demo": bench_consolidation_demo(),
        "migration_sweep": bench_threshold_sweep(),
    }


def main() -> None:
    report = run()
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {OUT_PATH}")
    d = report["consolidation_demo"]
    print(f"migration,consolidation,energy_saving={d['energy_saving']:.3f},"
          f"moves={d['migrated']['n_migrations']}")
    g = report["migration_sweep"]
    print(f"migration,sweep,points={g['jnp']['grid_points']},"
          f"scenarios_per_s={g['jnp']['scenarios_per_s']:.3f}")


if __name__ == "__main__":
    main()
