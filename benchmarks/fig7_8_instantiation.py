"""Paper Figures 7 & 8: time and memory to instantiate the simulation
environment, 100 -> 100 000 hosts.

Paper (Java, 2009): exponential time growth, <5 min at 100k hosts; linear
memory, 75 MB at 100k hosts.  Tensorized (struct-of-arrays): both LINEAR,
and ~3 orders of magnitude smaller — the beyond-paper headline for this
experiment.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import engine, scenarios


def state_bytes(tree) -> int:
    return sum(
        int(np.prod(l.shape)) * l.dtype.itemsize for l in jax.tree.leaves(tree)
    )


def run(n_hosts_list=(100, 1_000, 10_000, 100_000)) -> list[dict]:
    rows = []
    for n in n_hosts_list:
        t0 = time.perf_counter()
        scn = scenarios.fig7_8_scenario(n)
        st = engine.init_state(scn)
        jax.block_until_ready(st.free_ram)
        dt = time.perf_counter() - t0
        rows.append({
            "hosts": n,
            "instantiate_s": dt,
            "state_bytes": state_bytes(scn) + state_bytes(st),
        })
    return rows


def main():
    print("hosts,instantiate_s,state_MB,paper_time_s,paper_mem_MB")
    paper_t = {100: 0.2, 1_000: 0.8, 10_000: 9.0, 100_000: 300.0}   # Fig 7 (approx)
    paper_m = {100: 1.0, 1_000: 2.0, 10_000: 12.0, 100_000: 75.0}   # Fig 8 (approx)
    for r in run():
        print(f"{r['hosts']},{r['instantiate_s']:.4f},"
              f"{r['state_bytes'] / 1e6:.2f},"
              f"{paper_t[r['hosts']]},{paper_m[r['hosts']]}")


if __name__ == "__main__":
    main()
