"""Engine advance-sweep throughput: jnp reference vs Pallas kernel.

Seeds the perf trajectory with a machine-readable baseline: runs the raw
``advance_sweep`` kernel standalone (large C) and the full engine in both
routings (``Scenario.sweep_impl``), then writes ``BENCH_engine.json``.

    PYTHONPATH=src python -m benchmarks.engine_sweep

On CPU the Pallas kernel executes in interpret mode, so its numbers are a
correctness-seat baseline, not a speed claim — the Mosaic path lights up on
TPU (kernels/ops.py routing).
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scenarios, simulate
from repro.kernels import ops

OUT_PATH = "BENCH_engine.json"


def _time(fn, *args, n_rep: int = 5) -> float:
    out = fn(*args)                                # compile + warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n_rep):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n_rep


def bench_kernel(c: int = 100_000, n_rep: int = 5) -> dict:
    """Raw fused sweep: min-time-to-completion + depletion over C cloudlets."""
    rng = np.random.default_rng(0)
    rem = jnp.asarray(rng.uniform(1e3, 1e6, c).astype(np.float32))
    rate = jnp.asarray(rng.uniform(0.0, 1e3, c).astype(np.float32))
    active = rate > 1.0
    bound = jnp.asarray(1e4, jnp.float32)

    rows = {}
    for impl in ("jnp", "pallas"):
        advance = ops.resolve_advance(impl)
        fn = jax.jit(advance)
        wall = _time(fn, rem, rate, active, bound, n_rep=n_rep)
        rows[impl] = {
            "wall_s": wall,
            "cloudlets": c,
            "cloudlets_per_s": c / wall,
        }
    return rows


def bench_engine(n_hosts: int = 2_000, n_vms: int = 50, n_groups: int = 5,
                 n_rep: int = 3) -> dict:
    """Full engine, fig9/10-style workload, jnp vs Pallas routing."""
    rows = {}
    for impl in ("jnp", "pallas"):
        scn = scenarios.fig9_10_scenario(
            scenarios.SPACE_SHARED, n_hosts=n_hosts, n_vms=n_vms,
            n_groups=n_groups).replace(sweep_impl=impl)
        fn = jax.jit(simulate)
        wall = _time(fn, scn, n_rep=n_rep)
        res = fn(scn)
        n_events = int(res.n_events)
        rows[impl] = {
            "wall_s": wall,
            "n_events": n_events,
            "events_per_s": n_events / wall,
            "n_finished": int(res.n_finished),
        }
    return rows


def run() -> dict:
    report = {
        "backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() != "tpu",
        "advance_sweep_kernel": bench_kernel(),
        "engine_fig9_10": bench_engine(),
    }
    jn, pl = report["engine_fig9_10"]["jnp"], report["engine_fig9_10"]["pallas"]
    report["engine_speedup_pallas_vs_jnp"] = jn["wall_s"] / pl["wall_s"]
    return report


def main() -> None:
    report = run()
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {OUT_PATH}")
    for section in ("advance_sweep_kernel", "engine_fig9_10"):
        for impl, row in report[section].items():
            metrics = ",".join(f"{k}={v:.6g}" if isinstance(v, float) else
                               f"{k}={v}" for k, v in row.items())
            print(f"{section},{impl},{metrics}")


if __name__ == "__main__":
    main()
