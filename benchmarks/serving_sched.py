"""KV-cache-bound continuous batching through the event engine (§14).

Beyond-paper rows for the serving tentpole: an inference-fleet scenario
(diurnal request arrivals, block-granular KV admission, preemption,
continuous-batch decode) timed through the single event loop and as a
B=32 batch-major SLO campaign sweeping rate x kv_blocks x autoscale
threshold inside one compiled program, with TTFT/TPOT pooled by
``LatencyHistogramReducer``.  The gated numbers are
``serving_single.jnp.serving_requests_per_s`` and
``serving_batch.batch_major.serving_requests_per_s``
(``benchmarks/check_regression.py`` vs ``BENCH_baseline.json``).

A third, non-gated section keeps the PR-9 loop alive: the same CloudSim
policies driving the REAL ``repro.serving`` engine — simulated prediction
vs measured outcome (the paper's "evaluate before deploy" loop closed on
hardware).

    PYTHONPATH=src python -m benchmarks.serving_sched

Writes ``BENCH_serving.json``.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.core import (
    reducers,
    run_campaign,
    scenarios,
    simulate,
    stack_scenarios,
)

OUT_PATH = "BENCH_serving.json"


def _fleet(*, rate=2.0, kv_blocks=32.0, scale_up_thresh=0.75,
           n_requests=96, max_steps=None):
    return scenarios.serving_scenario(
        jax.random.PRNGKey(0), n_requests=n_requests, n_replicas=4,
        n_pool=2, kv_blocks=kv_blocks, rate=rate, autoscale=True,
        scale_up_thresh=scale_up_thresh, batch_degradation=0.1,
        median_prompt=96.0, median_new=64.0, deadline_rel=30.0,
        max_steps=max_steps)


def bench_single(n_requests: int = 96, n_rep: int = 5) -> dict:
    """One pressured inference fleet through the event loop: admission,
    block-boundary stops, eviction and continuous-batch decode all price
    the serving phase itself."""
    fn = jax.jit(simulate)
    scn = _fleet(n_requests=n_requests)
    res = fn(scn)                                     # compile + warm
    jax.block_until_ready(res)
    t0 = time.perf_counter()
    for _ in range(n_rep):
        res = fn(scn)
        jax.block_until_ready(res)
    wall = (time.perf_counter() - t0) / n_rep
    served = int(res.n_finished)
    assert served > 0
    return {
        "jnp": {
            "n_requests": n_requests,
            "n_served": served,
            "n_events": int(res.n_events),
            "wall_s": wall,
            "serving_requests_per_s": served / wall,
            "events_per_s": int(res.n_events) / wall,
            "ttft_p99_s": float(res.ttft_p99),
            "tpot_p99_s": float(res.tpot_p99),
        }
    }


def bench_batch(n_requests: int = 48, n_rep: int = 3) -> dict:
    """The SLO campaign surface: a rate x kv_blocks x autoscale-threshold
    grid (B=32) through the batch-major step loop, TTFT/TPOT tails pooled
    across the whole grid by streaming reducers."""
    grid = [
        dict(rate=r, kv_blocks=kv, scale_up_thresh=th)
        for r in (1.0, 1.5, 2.0, 3.0)
        for kv in (16.0, 24.0, 48.0, 64.0)
        for th in (0.6, 0.9)
    ]
    rows = [_fleet(n_requests=n_requests, max_steps=2000, **g)
            for g in grid]
    batched = stack_scenarios(rows)
    reduce = {
        "served": reducers.SumReducer("n_finished"),
        "ttft": reducers.LatencyHistogramReducer(
            "ttft", lo=0.0, hi=60.0, bins=256, qs=(0.5, 0.99)),
        "tpot": reducers.LatencyHistogramReducer(
            "tpot", lo=0.0, hi=1.0, bins=256, qs=(0.5, 0.99)),
        "violations": reducers.SumReducer("sla_violations"),
    }

    out = run_campaign(batched, chunk_size=8, reduce=reduce)
    jax.tree.map(jax.block_until_ready, out)          # compile + warm
    t0 = time.perf_counter()
    for _ in range(n_rep):
        out = run_campaign(batched, chunk_size=8, reduce=reduce)
        jax.tree.map(jax.block_until_ready, out)
    wall = (time.perf_counter() - t0) / n_rep
    served = int(np.asarray(out["served"]))
    assert served > 0
    return {
        "batch_major": {
            "batch": len(rows),
            "n_requests": len(rows) * n_requests,
            "n_served": served,
            "wall_s": wall,
            "serving_requests_per_s": served / wall,
        },
        "slo": {
            "ttft_p50_s": float(out["ttft"]["q0.5"]),
            "ttft_p99_s": float(out["ttft"]["q0.99"]),
            "tpot_p50_s": float(out["tpot"]["q0.5"]),
            "tpot_p99_s": float(out["tpot"]["q0.99"]),
            "n_sla_violations": int(np.asarray(out["violations"])),
        },
    }


def bench_crosscheck(n_requests=6, slots=2, new_tokens=8) -> dict:
    """CloudSim policies driving the REAL serving engine — simulated
    prediction vs measured outcome (not perf-gated; it exercises a tiny
    actual model)."""
    from repro.configs import get_config
    from repro.core import SPACE_SHARED, TIME_SHARED
    from repro.models import build_model
    from repro.serving import ServingEngine, choose_policy
    from repro.serving.scheduler import Request

    cfg = get_config("internlm2-1.8b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = [Request(rid=i, arrival=0.0, prompt_len=8,
                    max_new_tokens=new_tokens) for i in range(n_requests)]
    pol, pred = choose_policy(reqs, slots, tokens_per_sec=100.0)
    rows = []
    for name, policy in (("space", SPACE_SHARED), ("time", TIME_SHARED)):
        eng = ServingEngine(model, params, n_slots=slots, max_len=64,
                            policy=policy, quantum=4)
        rng = np.random.default_rng(0)
        for _ in range(n_requests):
            eng.submit(rng.integers(0, cfg.vocab, size=8),
                       max_new_tokens=new_tokens)
        out = eng.run_until_drained(max_steps=400)
        tats = [r.finish_time - r.arrival for r in out]
        rows.append({
            "policy": name,
            "measured_mean_tat": float(np.mean(tats)),
            "measured_makespan": eng.steps,
            "predicted_mean_tat": pred[name]["mean_tat"] * 100.0
            if pred else float("nan"),  # sim seconds @100 tok/s -> steps
        })
    return {"recommends": "space" if pol == 0 else "time", "rows": rows}


def run() -> dict:
    return {
        "backend": jax.default_backend(),
        "serving_single": bench_single(),
        "serving_batch": bench_batch(),
        "serving_crosscheck": bench_crosscheck(),
    }


def main() -> None:
    report = run()
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {OUT_PATH}")
    s = report["serving_single"]["jnp"]
    print(f"serving,single,requests_per_s={s['serving_requests_per_s']:.1f},"
          f"ttft_p99={s['ttft_p99_s']:.3f},tpot_p99={s['tpot_p99_s']:.4f}")
    b = report["serving_batch"]
    print(f"serving,batch,B={b['batch_major']['batch']},"
          f"requests_per_s={b['batch_major']['serving_requests_per_s']:.1f},"
          f"ttft_p99={b['slo']['ttft_p99_s']:.3f},"
          f"violations={b['slo']['n_sla_violations']}")
    c = report["serving_crosscheck"]
    for r in c["rows"]:
        print(f"serving,crosscheck,{r['policy']},"
              f"measured_tat={r['measured_mean_tat']:.1f},"
              f"predicted_tat={r['predicted_mean_tat']:.1f}")
    print(f"serving,crosscheck,recommends={c['recommends']}")


if __name__ == "__main__":
    main()
