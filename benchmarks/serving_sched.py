"""Beyond-paper: the CloudSim policies driving the REAL serving engine —
simulated prediction vs measured outcome (the paper's 'evaluate before
deploy' loop closed on hardware)."""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_config
from repro.core import SPACE_SHARED, TIME_SHARED
from repro.models import build_model
from repro.serving import ServingEngine, choose_policy
from repro.serving.scheduler import Request


def run(n_requests=6, slots=2, new_tokens=8):
    cfg = get_config("internlm2-1.8b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rows = []
    # prediction from the simulator
    reqs = [Request(rid=i, arrival=0.0, prompt_len=8,
                    max_new_tokens=new_tokens) for i in range(n_requests)]
    pol, pred = choose_policy(reqs, slots, tokens_per_sec=100.0)
    # measured on the engine
    for name, policy in (("space", SPACE_SHARED), ("time", TIME_SHARED)):
        eng = ServingEngine(model, params, n_slots=slots, max_len=64,
                            policy=policy, quantum=4)
        rng = np.random.default_rng(0)
        for _ in range(n_requests):
            eng.submit(rng.integers(0, cfg.vocab, size=8),
                       max_new_tokens=new_tokens)
        out = eng.run_until_drained(max_steps=400)
        tats = [r.finish_time - r.arrival for r in out]
        rows.append({
            "policy": name,
            "measured_mean_tat": float(np.mean(tats)),
            "measured_makespan": eng.steps,
            "predicted_mean_tat": pred[name]["mean_tat"] * 100.0
            if pred else float("nan"),  # sim seconds @100 tok/s -> steps
        })
    return pol, rows


def main():
    pol, rows = run()
    print("policy,measured_mean_tat_steps,measured_makespan_steps,"
          "sim_predicted_mean_tat_steps")
    for r in rows:
        print(f"{r['policy']},{r['measured_mean_tat']:.1f},"
              f"{r['measured_makespan']},{r['predicted_mean_tat']:.1f}")
    print(f"simulator_recommends,{'space' if pol == 0 else 'time'}")


if __name__ == "__main__":
    main()
