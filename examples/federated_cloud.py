"""The paper's federation experiment as a playground: sweep the peer
background load and watch the coordinator's migration decisions + the
Table-1 metrics respond.

    PYTHONPATH=src python examples/federated_cloud.py
"""
import jax

from repro.core import scenarios, simulate

print("peer_bg  migrations  meanTAT(fed)  makespan(fed)  TATcut%  MKcut%")
base = {False: jax.jit(simulate)(scenarios.table1_scenario(False))}
for bg in (3, 5, 7, 9):
    fed = jax.jit(simulate)(scenarios.table1_scenario(True, peer_background=bg))
    nofed = base[False]
    tat_cut = 100 * (1 - float(fed.mean_turnaround) / float(nofed.mean_turnaround))
    mk_cut = 100 * (1 - float(fed.makespan) / float(nofed.makespan))
    print(f"  {bg:2d}      {int(fed.n_migrations):3d}        "
          f"{float(fed.mean_turnaround):7.1f}      {float(fed.makespan):7.1f}"
          f"     {tat_cut:5.1f}   {mk_cut:5.1f}")
print("(paper Table 1: TAT cut 52.7%, makespan cut 21.3%)")
