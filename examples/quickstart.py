"""Quickstart: build a cloud, schedule work, compare policies — in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import (
    SPACE_SHARED, TIME_SHARED, Scenario, scenarios, simulate,
    stack_scenarios, run_campaign,
)

# a datacenter: 4 hosts x 2 cores x 1000 MIPS
hosts = scenarios.uniform_hosts(1, 4, cores=2, mips=1000.0)
# 6 single-core VMs, 2 tasks each (20 simulated minutes per task)
vms = scenarios.uniform_vms(6)
cls = scenarios.make_cloudlets(
    vm=np.tile(np.arange(6), 2),
    length_mi=np.full(12, 1_200_000.0),
    submit_t=np.repeat([0.0, 600.0], 6),
)

print("policy combo -> mean turnaround / makespan (seconds)")
for hp, hname in ((SPACE_SHARED, "space"), (TIME_SHARED, "time")):
    for vp, vname in ((SPACE_SHARED, "space"), (TIME_SHARED, "time")):
        scn = Scenario(hosts=hosts, vms=vms, cloudlets=cls,
                       market=scenarios.uniform_market(1),
                       policy=scenarios.make_policy(hp, vp))
        res = jax.jit(simulate)(scn)
        print(f"  host={hname:5s} vm={vname:5s} -> "
              f"{float(res.mean_turnaround):7.1f} / {float(res.makespan):7.1f}"
              f"   (cost ${float(res.total_cost):,.0f})")

# a campaign: every combo evaluated in ONE vmapped program
combos = [Scenario(hosts=hosts, vms=vms, cloudlets=cls,
                   market=scenarios.uniform_market(1),
                   policy=scenarios.make_policy(hp, vp))
          for hp in (0, 1) for vp in (0, 1)]
res = run_campaign(stack_scenarios(combos))
print("campaign (vmapped) makespans:", np.array(res.makespan))
