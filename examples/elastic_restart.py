"""Fault tolerance demo: train with injected node failures; the coordinator
restores from the latest checkpoint, evaluates its CloudSim restart plan,
and finishes the job.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import tempfile

from repro.configs import get_config
from repro.launch.elastic import ElasticRunner

cfg = get_config("internlm2-1.8b", smoke=True)
with tempfile.TemporaryDirectory() as d:
    runner = ElasticRunner(cfg, d, steps=30, global_batch=4, seq_len=32,
                           ckpt_every=6, n_workers=4)
    out = runner.run(fail_at_steps=[9, 20])
    print(f"restarts: {out['restarts']}")
    for e in out["events"]:
        if e["kind"] == "failure":
            print(f"  failure -> resume@{e['resume_step']} on "
                  f"{e['survivors']} workers; plan={e['plan']['choice']} "
                  f"(survivors {e['plan']['finish_on_survivors_s']:.0f}s vs "
                  f"repair {e['plan']['wait_for_repair_s']:.0f}s)")
    print(f"final loss: {out['result']['final_loss']:.4f}")
