"""Serving demo: continuous batching with the CloudSim predictive scheduler
re-planning the admission policy from live queue simulations.

    PYTHONPATH=src python examples/serve_model.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import ServingEngine

cfg = get_config("internlm2-1.8b", smoke=True)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

eng = ServingEngine(model, params, n_slots=2, max_len=96, replan_every=4)
rng = np.random.default_rng(0)
for i in range(6):
    eng.submit(rng.integers(0, cfg.vocab, size=8 + 4 * (i % 3)),
               max_new_tokens=6 + 2 * (i % 2))

while any(not r.done for r in eng.requests):
    info = eng.step()
    if info["finished"]:
        print(f"step {info['step']:3d}: finished {info['finished']} "
              f"(active={info['active']}, policy="
              f"{'space' if eng.sched.policy == 0 else 'time'})")

tats = [r.finish_time - r.arrival for r in eng.requests]
print(f"all {len(eng.requests)} requests served; "
      f"mean turnaround {np.mean(tats):.1f} engine steps, "
      f"makespan {eng.steps} steps")
