"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
the synthetic Markov pipeline, with periodic async checkpointing.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

(A scaled-down internlm2-family config: 12L x 768 with a 8192 vocab ~= 98M
params.  On TPU the same driver jits under make_production_mesh(); here it
runs on CPU, so the default step count keeps wall time reasonable — pass
--steps 300 for the full demonstration.)
"""
import argparse

import numpy as np

from repro.launch.train import run_training
from repro.models.config import ModelConfig


def config_100m() -> ModelConfig:
    return ModelConfig(
        name="demo-98m", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_head=64,
        d_ff=2048, vocab=8192, remat=False, dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = config_100m()
    n_params = cfg.param_count()
    print(f"[100m] params = {n_params / 1e6:.1f}M, ln(V) = "
          f"{np.log(cfg.vocab):.3f}")
    out = run_training(
        cfg, steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq_len, lr=6e-4, ckpt_dir=args.ckpt_dir,
        ckpt_every=50, log_every=10,
    )
    first, last = out["losses"][0], out["final_loss"]
    print(f"[100m] loss {first:.3f} -> {last:.3f} over {out['steps_run']} steps")
    assert last < first, "model did not learn"


if __name__ == "__main__":
    main()
