"""Successive-halving policy search over an MTBF x ckpt x migration grid.

The DESIGN.md §12 search loop end to end: sample candidate reliability
configurations, simulate each as one row of a streamed campaign (the
``[n, ...]`` results are never materialized), promote the top half to a
longer horizon, and print the frontier — which checkpoint interval and
migration posture survive which failure regimes, and the single best row.

The MTBF knob is a *workload* dimension, not a ``Policy`` field: the
``instantiate`` hook turns the sampled ``mtbf_s`` column into vmapped
``workload.host_outages`` schedules (one seeded outage trace per
candidate).  Everything — outage draws, checkpoint interval, migration
threshold, the per-rung horizon — is traced, so both rungs and both runs
of this script re-enter ONE compiled chunk program (simlint R5 probes
exactly this loop).

    PYTHONPATH=src python examples/campaign_search.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scenarios, workload
from repro.core.search import successive_halving

N0 = 16              # initial candidate population
RUNG_HORIZONS = (10_000.0, 20_000.0)   # cheap screen, then full fidelity

SPACE = {
    # Policy knobs (traced fields, vmapped into template.policy)
    "ckpt_interval": (50.0, 200.0, 800.0, 3.0e38),     # INF = no checkpoints
    "migrate_balance_thresh": (0.75, 1e9),             # on / off
    # workload knob (routed to `instantiate` below); short MTBFs so every
    # candidate's run actually sees failures inside the horizon
    "mtbf_s": (120.0, 300.0, 700.0),
}


def instantiate(template, extras, n, key):
    """mtbf_s column -> per-candidate seeded outage schedules."""
    d, h, k = template.outages.fail_t.shape
    keys = jax.random.split(key, n)
    outages = jax.vmap(
        lambda kk, m: workload.host_outages(kk, d, h, k, m, 400.0)
    )(keys, extras["mtbf_s"])
    return {"outages": outages}


def _fmt_thresh(v):
    return "off" if float(v) > 1e6 else f"{float(v):.2f}"


def main():
    template = scenarios.reliability_scenario(
        key=jax.random.PRNGKey(0), federation=True, sensor_interval=50.0)
    out = successive_halving(
        template, SPACE, key=jax.random.PRNGKey(42), n0=N0,
        fidelities=RUNG_HORIZONS, metric="total_cost", chunk_size=8,
        instantiate=instantiate,
    )

    print("rung  horizon   n   best-so-far (total_cost)")
    for i, rung in enumerate(out["rungs"]):
        v = np.array(rung["values"])
        print(f"{i:>4}  {rung['fidelity']:>7.0f}  {len(v):>2}   {v.min():.2f}")

    print("\nfrontier after rung 0 (survivors, cheapest first):")
    print("   id    mtbf_s  ckpt_interval  balance_thresh  total_cost")
    r0 = out["rungs"][0]
    params = {k: np.array(v) for k, v in out["params"].items()}
    order = np.argsort(np.array(r0["values"]))
    for j in order[: N0 // 2]:
        i = int(np.array(r0["candidates"])[j])
        ckpt = params["ckpt_interval"][i]
        print(f"  #{i:>3}  {params['mtbf_s'][i]:>8.0f}  "
              f"{'off (INF)' if ckpt > 1e30 else f'{ckpt:.0f}':>13}  "
              f"{_fmt_thresh(params['migrate_balance_thresh'][i]):>14}  "
              f"{float(np.array(r0['values'])[j]):>10.2f}")

    best = out["best_params"]
    ckpt = float(best["ckpt_interval"])
    print("\nwinner:")
    print(f"  mtbf_s                 = {float(best['mtbf_s']):.0f}")
    print(f"  ckpt_interval          = "
          f"{'off (INF)' if ckpt > 1e30 else f'{ckpt:.0f}'}")
    print(f"  migrate_balance_thresh = "
          f"{_fmt_thresh(best['migrate_balance_thresh'])}")
    print(f"  total_cost             = {float(out['best_value']):.2f}")


if __name__ == "__main__":
    main()
