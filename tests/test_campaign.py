"""Campaign API: trace variant, chunked execution, stacking validation,
sharded execution (4-device subprocess)."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SPACE_SHARED,
    run_campaign,
    scenarios,
    simulate_trace,
    stack_scenarios,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Donation must actually apply: a donated-but-unusable buffer means the
# advertised per-chunk reuse silently regressed to a no-op (see the
# _donate_mask machinery in core/campaign.py).
pytestmark = [
    pytest.mark.tier1,
    pytest.mark.filterwarnings("error:Some donated buffers were not usable"),
]


def test_simulate_trace_progress_curves():
    """Fig 9/10-style progress sampling: fractions are monotone in time and
    reach 1.0 for finished work."""
    scn = scenarios.fig9_10_scenario(SPACE_SHARED, n_hosts=50, n_vms=5,
                                     n_groups=3)
    ts = jnp.asarray(np.arange(0.0, 4000.0, 250.0, dtype=np.float32))
    res, prog = simulate_trace(scn, ts)
    prog = np.array(prog)
    assert prog.shape == (len(ts), scn.cloudlets.n_cloudlets)
    assert (np.diff(prog, axis=0) >= -1e-5).all()          # monotone
    assert np.allclose(prog[-1][np.array(res.finish_t) <= 3750.0], 1.0,
                       atol=1e-3)
    # first group (submit 0): progress at sample t is t/1200 (dedicated cores)
    first = np.array(scn.cloudlets.submit_t) == 0.0
    t_idx = int(np.searchsorted(np.array(ts), 750.0))
    assert np.allclose(prog[t_idx][first], 750.0 / 1200.0, atol=0.02)


def test_chunked_campaign_matches_unchunked():
    """Chunking (with per-chunk buffer donation + trailing-chunk padding)
    must be invisible in the results — including a non-dividing chunk size."""
    base = [scenarios.fig4_scenario(hp, vp) for hp in (0, 1) for vp in (0, 1)]
    batched = stack_scenarios(base * 5)          # 20 scenarios
    whole = run_campaign(batched)
    for chunk in (4, 7, 32):                      # divides / ragged / > n
        chunked = run_campaign(batched, chunk_size=chunk)
        np.testing.assert_array_equal(
            np.array(whole.finish_t), np.array(chunked.finish_t))
        np.testing.assert_array_equal(
            np.array(whole.total_cost), np.array(chunked.total_cost))


def test_chunked_campaign_1024_scenarios():
    """Acceptance: a >=1024-scenario fig4 campaign runs chunked end to end."""
    base = [scenarios.fig4_scenario(hp, vp) for hp in (0, 1) for vp in (0, 1)]
    batched = stack_scenarios(base * 256)         # 1024 scenarios
    res = run_campaign(batched, chunk_size=128)
    fin = np.array(res.n_finished)
    assert fin.shape == (1024,)
    assert (fin == 8).all()


def test_run_campaign_rejects_bad_chunk_size():
    batched = stack_scenarios([scenarios.fig4_scenario(0, 0)] * 2)
    with pytest.raises(ValueError, match="chunk_size"):
        run_campaign(batched, chunk_size=0)


def test_stack_scenarios_validates_static_fields():
    a = scenarios.fig4_scenario(0, 0)
    with pytest.raises(ValueError, match="max_steps"):
        stack_scenarios([a, a.replace(max_steps=512)])
    with pytest.raises(ValueError, match="sweep_impl"):
        stack_scenarios([a, a.replace(sweep_impl="pallas")])
    with pytest.raises(ValueError, match="empty"):
        stack_scenarios([])


def test_stack_scenarios_validates_structure():
    from repro.core.energy import PowerModel

    a = scenarios.fig4_scenario(0, 0)
    b = a.replace(power=PowerModel.uniform(1))
    with pytest.raises(ValueError, match="structure"):
        stack_scenarios([a, b])


def test_run_campaign_sharded_subprocess():
    code = """
import jax, numpy as np
from jax.sharding import Mesh
from repro.core import scenarios, stack_scenarios, run_campaign, run_campaign_sharded

scns = [scenarios.fig4_scenario(hp, vp) for hp in (0,1) for vp in (0,1)] * 2
batched = stack_scenarios(scns)
mesh = Mesh(np.array(jax.devices()).reshape(4, 1), ("data", "model"))
local = run_campaign(batched)
sharded = run_campaign_sharded(batched, mesh)
np.testing.assert_allclose(np.array(local.finish_t), np.array(sharded.finish_t), rtol=1e-6)
print("SHARDED_OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED_OK" in out.stdout
