"""Batch-major step loop (DESIGN.md §10): a stacked ``[B, ...]`` campaign
through ``simulate`` must be a *perf* path, never a semantic fork.

Four families:

* **bitwise identity** — every row of the batch-major result equals a
  Python loop of per-scenario ``simulate``, bit for bit, across scenario
  constructors (policies, federation, outages, autoscaling pools).
* **early-exit masking** — rows with wildly different event counts
  (federated table1 vs non-federated: ~100 vs ~4 events) stay frozen at
  their own final state while the longest row keeps stepping.
* **conservation through the batch path** — the invariant suite's
  rate·dt-integral instrument, re-run per-row inside the batch loop,
  still balances depleted work on a mixed done/live batch.
* **driver equivalence** — ``simulate_trace`` / ``simulate_history``
  through the batch path reproduce their per-row outputs.

Plus the kernel-level contract the engine relies on: rank-2 (batch-major)
``advance_sweep`` inputs match a vmap of the rank-1 kernel on both
routings, and the ``advance_block`` tile heuristic respects its
floor/cap bounds.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import test_invariants as ti
from repro.core import (
    SPACE_SHARED,
    TIME_SHARED,
    scenarios,
    simulate_history,
    simulate_instrumented,
    simulate_trace,
    stack_scenarios,
)
from repro.core.engine import is_batched, scenario_row
from repro.kernels import ops, ref

pytestmark = pytest.mark.tier1


@jax.jit
def _run(scn):
    # one private jit target for single AND stacked scenarios: the driver
    # picks the batch-major loop by rank (engine.is_batched), so each shape
    # is its own cache entry but the traced source is identical
    return simulate_instrumented(scn)[0]


def _row(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


def _assert_trees_bitwise(name, got, want):
    mism = [
        jax.tree_util.keystr(path)
        for (path, a), b in zip(
            jax.tree_util.tree_flatten_with_path(got)[0],
            jax.tree.leaves(want),
        )
        if not bool(jnp.array_equal(a, b))
    ]
    assert not mism, f"{name}: batch != single at {mism}"


def _assert_rows_bitwise(name, batched_out, single_outs):
    for i, single in enumerate(single_outs):
        _assert_trees_bitwise(f"{name} row {i}", _row(batched_out, i), single)


def _scenario_batches():
    """Stackable row groups, one per scenario-constructor family, with
    rows varied along a traced axis (policy flags, workload, RNG key)."""
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    return [
        ("fig4_policies", [
            scenarios.fig4_scenario(SPACE_SHARED, SPACE_SHARED),
            scenarios.fig4_scenario(TIME_SHARED, TIME_SHARED),
            scenarios.fig4_scenario(SPACE_SHARED, TIME_SHARED),
        ]),
        ("fig9_10_lengths", [
            scenarios.fig9_10_scenario(
                TIME_SHARED, n_hosts=40, n_vms=4, n_groups=2,
                task_mi=mi)
            for mi in (600_000.0, 1_200_000.0)
        ]),
        ("table1_mixed", [
            scenarios.table1_scenario(True),
            scenarios.table1_scenario(False),
        ]),
        ("generated_keys", [
            scenarios.generated_scenario(
                k, kind="poisson", n_cloudlets=16, n_vms=4, n_hosts=4,
                rate=0.2, median_mi=10_000.0)
            for k in (k1, k2)
        ]),
        ("autoscale", [
            scenarios.autoscale_scenario(k1, scale_down_thresh=0.05),
            scenarios.autoscale_scenario(k2, scale_down_thresh=0.05),
        ]),
        ("reliability", [
            scenarios.reliability_scenario(k1, evacuation=True,
                                           ckpt_interval=25_000.0),
            scenarios.reliability_scenario(k2, evacuation=True,
                                           ckpt_interval=25_000.0),
        ]),
        ("evacuation", [
            scenarios.evacuation_scenario(),
            scenarios.evacuation_scenario(evacuation=False,
                                          ckpt_interval=3.0e38),
        ]),
    ]


_BATCH_IDS = [name for name, _ in _scenario_batches()]


@pytest.mark.parametrize("name,rows", _scenario_batches(), ids=_BATCH_IDS)
def test_batch_rows_bitwise_identical(name, rows):
    batched = stack_scenarios(rows)
    assert is_batched(batched) and not is_batched(rows[0])
    res_b = _run(batched)
    singles = [_run(r) for r in rows]
    _assert_rows_bitwise(name, res_b, singles)


def test_early_exit_freezes_finished_rows():
    """Rows finishing at different event counts: once a row's step_cond
    drops, the live mask must freeze it bitwise while others continue."""
    rows = [scenarios.table1_scenario(True), scenarios.table1_scenario(False)]
    res_b = _run(stack_scenarios(rows))
    n_ev = np.array(res_b.n_events)
    # premise: the batch genuinely mixes a long row with a short one
    assert n_ev[0] >= n_ev[1] + 10, f"rows not heterogeneous: {n_ev}"
    singles = [_run(r) for r in rows]
    _assert_rows_bitwise("table1_mixed", res_b, singles)


def test_batch_conservation_mixed():
    """Work conservation on a mixed done/live batch: each row's rate·dt
    integral (accumulated inside the batch loop, so frozen rows must stop
    accruing) balances its depleted work."""
    rows = [scenarios.table1_scenario(True), scenarios.table1_scenario(False)]
    batched = stack_scenarios(rows)
    res, out = simulate_instrumented(batched, (ti._ConservationInstrument(),))
    executed = np.array(out["conservation"]["executed_mi"])
    rem = np.array(out["conservation"]["rem_mi"])
    rollback = np.array(out["conservation"]["rollback_mi"])
    assert (rollback == 0).all()  # no outage schedule in table1
    for i, scn in enumerate(rows):
        length = np.array(scn.cloudlets.length_mi)
        exists = np.array(scn.cloudlets.exists)
        np.testing.assert_allclose(
            executed[i][exists], (length - rem[i])[exists],
            rtol=1e-4, atol=1.0,
            err_msg=f"row {i}: rate·dt integral != depleted work")


def test_trace_equivalence_through_batch_path():
    ts = jnp.asarray([0.0, 900.0, 1800.0, 3600.0], jnp.float32)
    rows = [
        scenarios.fig9_10_scenario(TIME_SHARED, n_hosts=40, n_vms=4,
                                   n_groups=2, task_mi=mi)
        for mi in (600_000.0, 1_200_000.0)
    ]
    res_b, prog_b = simulate_trace(stack_scenarios(rows), ts)
    assert prog_b.shape == (len(rows), ts.shape[0], rows[0].cloudlets.n_cloudlets)
    for i, scn in enumerate(rows):
        res_i, prog_i = simulate_trace(scn, ts)
        _assert_trees_bitwise(f"trace row {i}", _row(res_b, i), res_i)
        assert bool(jnp.array_equal(prog_b[i], prog_i))


def test_history_through_batch_path():
    rows = [
        scenarios.fig4_scenario(SPACE_SHARED, SPACE_SHARED),
        scenarios.fig4_scenario(TIME_SHARED, TIME_SHARED),
    ]
    res_b, hist_b = simulate_history(stack_scenarios(rows))
    for i, scn in enumerate(rows):
        res_i, hist_i = simulate_history(scn)
        _assert_trees_bitwise(f"history result row {i}", _row(res_b, i), res_i)
        # History stacks along axis 1: leaves are [T, B, ...] (the event
        # axis stays leading so per-event slicing is uniform)
        got = jax.tree.map(lambda x: x[:, i], hist_b)
        _assert_trees_bitwise(f"history log row {i}", got, hist_i)


def test_scenario_row_roundtrip():
    rows = [scenarios.fig4_scenario(SPACE_SHARED, SPACE_SHARED)] * 2
    batched = stack_scenarios(rows)
    row0 = scenario_row(batched)
    assert not is_batched(row0)
    assert jax.tree.structure(row0) == jax.tree.structure(rows[0])
    for a, b in zip(jax.tree.leaves(row0), jax.tree.leaves(rows[0])):
        assert bool(jnp.array_equal(a, b))


# ---------------------------------------------------------------------------
# kernel-level batch contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
@pytest.mark.parametrize("b,c", [(4, 30), (8, 257)])
def test_advance_rank2_matches_vmap_of_rank1(impl, b, c):
    rng = np.random.default_rng(7)
    rem = jnp.asarray(rng.uniform(0.0, 1e5, (b, c)).astype(np.float32))
    rate = jnp.asarray(rng.uniform(0.0, 1e3, (b, c)).astype(np.float32))
    active = rate > 100.0
    bound = jnp.asarray(rng.uniform(1.0, 1e3, (b,)).astype(np.float32))

    advance = ops.resolve_advance(impl)
    dt2, rem2 = advance(rem, rate, active, bound)
    dt1, rem1 = jax.vmap(ref.advance_sweep_ref)(rem, rate, active, bound)
    assert dt2.shape == (b,) and rem2.shape == (b, c)
    if impl == "jnp":
        assert bool(jnp.array_equal(dt2, dt1))
        assert bool(jnp.array_equal(rem2, rem1))
    else:
        np.testing.assert_allclose(np.array(dt2), np.array(dt1),
                                   rtol=1e-6, atol=1e-4)
        np.testing.assert_allclose(np.array(rem2), np.array(rem1),
                                   rtol=1e-6, atol=1e-2)


def test_advance_block_heuristic():
    assert ops.advance_block(1) == 128          # floor: one lane-width tile
    assert ops.advance_block(128) == 128
    assert ops.advance_block(129) == 256        # next pow2 covering the row
    assert ops.advance_block(100_000) == 1 << 17
    assert ops.advance_block(1 << 20) == ops._MAX_BLOCK  # cap
