"""Per-kernel interpret-mode vs pure-jnp-oracle allclose, swept over
shapes/dtypes (the (c) deliverable contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.vm_update import advance_sweep_pallas

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------- vm_update
@pytest.mark.parametrize("c", [1, 7, 100, 1000, 4096])
@pytest.mark.parametrize("block", [128, 1024])
def test_advance_sweep_shapes(c, block):
    rem = jnp.asarray(RNG.uniform(0.1, 100, c).astype(np.float32))
    rate = jnp.asarray(RNG.uniform(0, 5, c).astype(np.float32))
    active = jnp.asarray(RNG.random(c) > 0.3)
    bound = jnp.float32(RNG.uniform(0.1, 50))
    dt0, nr0 = ref.advance_sweep_ref(rem, rate, active, bound)
    dt1, nr1 = advance_sweep_pallas(rem, rate, active, bound, block=block)
    np.testing.assert_allclose(float(dt0), float(dt1), rtol=1e-6)
    np.testing.assert_allclose(np.array(nr0), np.array(nr1), rtol=1e-5,
                               atol=1e-5)


# deterministic property sweep (hypothesis is absent in the container image;
# each seed derives a random cloudlet count, covering the same space)
@pytest.mark.parametrize("seed", range(20))
def test_advance_sweep_property(seed):
    rng = np.random.default_rng(seed)
    c = int(rng.integers(1, 301))
    rem = jnp.asarray(rng.uniform(0.01, 10, c).astype(np.float32))
    rate = jnp.asarray(rng.uniform(0, 2, c).astype(np.float32))
    active = jnp.asarray(rng.random(c) > 0.5)
    bound = jnp.float32(rng.uniform(0.01, 5))
    dt, nr = advance_sweep_pallas(rem, rate, active, bound, block=128)
    # dt never exceeds the bound; no remaining work goes negative; at least
    # one active cloudlet hits zero if dt < bound
    assert float(dt) <= float(bound) + 1e-6
    assert (np.array(nr) >= 0).all()
    act = np.array(active) & (np.array(rate) > 0)
    if act.any() and float(dt) < float(bound) - 1e-6:
        assert np.isclose(np.array(nr)[act].min(), 0.0, atol=1e-3)


# --------------------------------------------- vm_update ragged-row fallback
#
# Rows longer than one tile take the two-phase sub-grid (B, 2, nb).  A
# non-power-of-two nb (e.g. 3 tiles) is the raggedest case: the reduction
# crosses tile seams that don't align with any power-of-two split.  Contract:
#   * dt is BITWISE equal to the jnp oracle — f32 min is order-exact, so
#     tiling the reduction may not change a single bit;
#   * rem' is BITWISE equal to the fused single-tile kernel — falling back
#     must not change the kernel's math — and within 1 ULP of the oracle
#     (XLA contracts the oracle's rem - rate*dt into an FMA; the kernel's
#     separate mul/sub rounds the product, so exactly-finishing cloudlets
#     can land 1 ULP apart; this is the only permitted divergence).

def _advance_case(rng, b, c):
    rem = jnp.asarray(rng.uniform(0.1, 100, (b, c)).astype(np.float32))
    rate = jnp.asarray(rng.uniform(0, 5, (b, c)).astype(np.float32))
    active = jnp.asarray(rng.random((b, c)) > 0.3)
    bound = jnp.asarray(rng.uniform(0.1, 50, (b,)).astype(np.float32))
    return rem, rate, active, bound


@pytest.mark.parametrize("c,block,nb", [(300, 128, 3), (1280, 256, 5)])
def test_advance_ragged_tiles_parity(c, block, nb):
    from repro.kernels.vm_update import kernel_plan

    plan = kernel_plan(2, c, block)
    assert plan["variant"] == "two_phase" and plan["nb"] == nb

    rem, rate, active, bound = _advance_case(np.random.default_rng(c), 2, c)
    dt0, nr0 = ref.advance_sweep_ref(rem, rate, active, bound)
    dt1, nr1 = advance_sweep_pallas(rem, rate, active, bound, block=block)
    # same inputs through the FUSED kernel (block covering the whole row):
    # the fallback's sliced reduction must reproduce it bit-for-bit
    dt2, nr2 = advance_sweep_pallas(rem, rate, active, bound, block=2048)
    np.testing.assert_array_equal(np.array(dt0), np.array(dt1))
    np.testing.assert_array_equal(np.array(dt1), np.array(dt2))
    np.testing.assert_array_equal(np.array(nr1), np.array(nr2))
    np.testing.assert_allclose(np.array(nr0), np.array(nr1),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.tier1
def test_advance_resolver_fallback_frontier():
    """Through ``ops.resolve_advance`` the two-phase path only engages past
    the 2**17 tile cap: C = 3 * 2**17 is the smallest non-pow-2-nb row the
    resolver can actually produce (nb = 3)."""
    from repro.kernels import ops
    from repro.kernels.vm_update import kernel_plan

    c = 3 * ops._MAX_BLOCK
    assert ops.advance_block(c) == ops._MAX_BLOCK
    plan = kernel_plan(1, c, ops.advance_block(c))
    assert plan["variant"] == "two_phase" and plan["nb"] == 3

    rng = np.random.default_rng(17)
    rem, rate, active, bound = _advance_case(rng, 1, c)
    # rank-1 (single-scenario) through the resolver, both impls
    args = (rem[0], rate[0], active[0], bound[0])
    dt0, nr0 = ops.resolve_advance("jnp")(*args)
    dt1, nr1 = ops.resolve_advance("pallas")(*args)
    assert np.array(dt1).shape == ()
    np.testing.assert_array_equal(np.array(dt0), np.array(dt1))
    np.testing.assert_allclose(np.array(nr0), np.array(nr1),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.tier1
def test_advance_resolver_batch_major_fallback():
    from repro.kernels import ops

    c = 3 * ops._MAX_BLOCK
    rem, rate, active, bound = _advance_case(np.random.default_rng(18), 2, c)
    dt0, nr0 = ops.resolve_advance("jnp")(rem, rate, active, bound)
    dt1, nr1 = ops.resolve_advance("pallas")(rem, rate, active, bound)
    assert np.array(dt1).shape == (2,)
    np.testing.assert_array_equal(np.array(dt0), np.array(dt1))
    np.testing.assert_allclose(np.array(nr0), np.array(nr1),
                               rtol=1e-6, atol=1e-5)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hk,sq,sk,d",
    [
        (1, 2, 2, 64, 64, 32),     # MHA
        (2, 4, 2, 128, 128, 64),   # GQA
        (1, 8, 1, 96, 224, 64),    # MQA, ragged kv / padding path
        (1, 4, 4, 1, 256, 64),     # decode-like single query
    ],
)
def test_flash_attention_shapes(b, hq, hk, sq, sk, d, dtype):
    q = jnp.asarray(RNG.standard_normal((b, hq, sq, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, hk, sk, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, hk, sk, d)), dtype)
    o0 = ref.attention_ref(q, k, v, causal=True)
    o1 = flash_attention_pallas(q, k, v, causal=True, bq=64, bk=64)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.array(o0, np.float32), np.array(o1, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize(
    "kw",
    [
        dict(causal=False),
        dict(causal=True, window=32),
        dict(causal=True, softcap=20.0),
        dict(causal=True, window=48, softcap=50.0),
    ],
)
def test_flash_attention_variants(kw):
    b, hq, hk, s, d = 2, 4, 2, 160, 32
    q = jnp.asarray(RNG.standard_normal((b, hq, s, d)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((b, hk, s, d)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((b, hk, s, d)).astype(np.float32))
    o0 = ref.attention_ref(q, k, v, **kw)
    o1 = flash_attention_pallas(q, k, v, bq=64, bk=64, **kw)
    np.testing.assert_allclose(np.array(o0), np.array(o1), atol=2e-5, rtol=2e-5)


def test_flash_vs_xla_flash():
    """The model's XLA online-softmax path == oracle too."""
    from repro.models.attention import flash_xla

    b, hq, hk, s, d = 1, 4, 2, 200, 32
    q = jnp.asarray(RNG.standard_normal((b, hq, s, d)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((b, hk, s, d)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((b, hk, s, d)).astype(np.float32))
    o0 = ref.attention_ref(q, k, v, causal=True, window=64)
    o1 = flash_xla(q, k, v, causal=True, window=64, softcap=0.0,
                   scale=d ** -0.5, chunk=64)
    np.testing.assert_allclose(np.array(o0), np.array(o1), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- ssd scan
@pytest.mark.parametrize(
    "b,s,h,p,g,n,chunk",
    [
        (1, 64, 2, 16, 1, 16, 32),
        (2, 128, 4, 32, 2, 32, 64),
        (1, 96, 2, 16, 1, 32, 32),   # padding path (96 % 64 != 0 w/ chunk 32)
    ],
)
def test_ssd_scan_shapes(b, s, h, p, g, n, chunk):
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)).astype(np.float32)) * 0.5
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, h)).astype(np.float32))
    A = jnp.asarray(-RNG.uniform(0.5, 2, h).astype(np.float32))
    Bm = jnp.asarray(RNG.standard_normal((b, s, g, n)).astype(np.float32)) * 0.3
    Cm = jnp.asarray(RNG.standard_normal((b, s, g, n)).astype(np.float32)) * 0.3
    D = jnp.asarray(RNG.uniform(0, 1, h).astype(np.float32))
    y_seq = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    y_chunk = ref.ssd_chunked_ref(x, dt, A, Bm, Cm, D,
                                  chunk=min(chunk, s))
    y_pl = ssd_scan_pallas(x, dt, A, Bm, Cm, D, chunk=chunk)
    np.testing.assert_allclose(np.array(y_seq), np.array(y_chunk),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.array(y_seq), np.array(y_pl),
                               atol=2e-4, rtol=2e-4)


def test_ssd_chunked_final_state():
    """return_state must equal the sequential scan's final hidden state."""
    b, s, h, p, g, n = 1, 64, 2, 8, 1, 16
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)).astype(np.float32)) * 0.5
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, h)).astype(np.float32))
    A = jnp.asarray(-RNG.uniform(0.5, 2, h).astype(np.float32))
    Bm = jnp.asarray(RNG.standard_normal((b, s, g, n)).astype(np.float32)) * 0.3
    Cm = jnp.asarray(RNG.standard_normal((b, s, g, n)).astype(np.float32)) * 0.3
    D = jnp.zeros((h,), jnp.float32)
    _, h_chunk = ref.ssd_chunked_ref(x, dt, A, Bm, Cm, D, chunk=32,
                                     return_state=True)
    # sequential reference state
    import jax

    Bh = jnp.repeat(Bm, h // g, axis=2)

    def step(hs, t):
        decay = jnp.exp(dt[:, t] * A)[..., None, None]
        upd = (dt[:, t][..., None, None] * x[:, t][..., None]) * Bh[:, t][:, :, None, :]
        return decay * hs + upd, None

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    h_seq, _ = jax.lax.scan(step, h0, jnp.arange(s))
    np.testing.assert_allclose(np.array(h_seq), np.array(h_chunk),
                               atol=2e-4, rtol=2e-4)
