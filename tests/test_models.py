"""Per-architecture smoke tests (reduced configs) + serving consistency.

For every assigned arch: one forward/train step on CPU asserting output
shapes and finiteness, and decode-from-prefill == teacher-forced logits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.models import lm as lm_mod

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B, S, with_labels=True):
    batch = {"tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S)
             % cfg.vocab}
    if with_labels:
        batch["labels"] = jnp.ones((B, S), jnp.int32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.full(
            (B, cfg.encoder.n_ctx, cfg.d_model), 0.1, jnp.float32)
    if cfg.family == "vlm":
        batch["tokens"] = batch["tokens"][:, : S - cfg.n_frontend_tokens]
        batch["frontend_embeds"] = jnp.full(
            (B, cfg.n_frontend_tokens, cfg.d_model), 0.1, jnp.float32)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 32
    batch = _batch_for(cfg, B, S)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: degenerate grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 16

    if cfg.family == "vlm":
        pytest.skip("vlm decode exercised via dryrun (3D positions)")
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab, jnp.int32)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.encoder.n_ctx, cfg.d_model), jnp.float32) * 0.1
        from repro.models import encdec, layers

        enc = encdec.encode(params, cfg, batch["frames"])
        hid = encdec._dec_trunk(params, cfg, toks, enc)
        full = layers.unembed(hid, params["embed"])
    else:
        full = lm_mod.lm_logits(params, cfg, toks)

    P = S - 3
    pb = dict(batch)
    pb["tokens"] = toks[:, :P]
    lg, caches = model.prefill(params, pb, S)
    np.testing.assert_allclose(np.array(lg), np.array(full[:, P - 1]),
                               atol=2e-4, rtol=2e-4)
    for i in range(2):
        lg, caches = model.decode_step(
            params, caches, toks[:, P + i][:, None],
            jnp.full((B,), P + i, jnp.int32))
        np.testing.assert_allclose(np.array(lg), np.array(full[:, P + i]),
                                   atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_entry_points(arch):
    """input_specs trees must match the actual call signatures (eval_shape)."""
    from repro.models import ShapeSpec

    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    pshapes = jax.eval_shape(model.init, KEY)
    # scale the cells down so eval_shape stays cheap
    cells = [
        ShapeSpec("train", 64, 4, "train"),
        ShapeSpec("prefill", 64, 2, "prefill"),
        ShapeSpec("decode", 64, 2, "decode"),
    ]
    for cell in cells:
        specs = model.input_specs(cell)
        if cell.kind == "train":
            out = jax.eval_shape(model.loss, pshapes, specs["batch"])
            assert out.shape == ()
        elif cell.kind == "prefill":
            out = jax.eval_shape(
                lambda p, b: model.prefill(p, b, cell.seq_len),
                pshapes, specs["batch"])
        else:
            logits, _ = jax.eval_shape(
                model.decode_step, pshapes, specs["caches"], specs["token"],
                specs["pos"])
            assert logits.shape == (cell.global_batch, cfg.vocab)


def test_param_count_matches_init():
    for arch in ("internlm2-1.8b", "granite-moe-1b-a400m", "mamba2-130m"):
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        pshapes = jax.eval_shape(model.init, KEY)
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(pshapes))
        analytic = cfg.param_count()
        # analytic model ignores tiny leaves (dt_bias etc.) — within 2%
        assert abs(actual - analytic) / actual < 0.02, (
            f"{arch}: analytic {analytic} vs actual {actual}")


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment block."""
    checks = {
        "phi3-mini-3.8b": dict(n_layers=32, d_model=3072, n_heads=32,
                               n_kv_heads=32, d_ff=8192, vocab=32064),
        "qwen3-32b": dict(n_layers=64, d_model=5120, n_heads=64,
                          n_kv_heads=8, d_ff=25600, vocab=151936),
        "gemma2-27b": dict(n_layers=46, d_model=4608, n_heads=32,
                           n_kv_heads=16, d_ff=36864, vocab=256000),
        "internlm2-1.8b": dict(n_layers=24, d_model=2048, n_heads=16,
                               n_kv_heads=8, d_ff=8192, vocab=92544),
        "jamba-v0.1-52b": dict(n_layers=32, d_model=4096, n_heads=32,
                               n_kv_heads=8, d_ff=14336, vocab=65536),
        "whisper-large-v3": dict(n_layers=32, d_model=1280, n_heads=20,
                                 n_kv_heads=20, d_ff=5120, vocab=51866),
        "mamba2-130m": dict(n_layers=24, d_model=768, d_ff=0, vocab=50280),
        "qwen3-moe-235b-a22b": dict(n_layers=94, d_model=4096, n_heads=64,
                                    n_kv_heads=4, vocab=151936),
        "granite-moe-1b-a400m": dict(n_layers=24, d_model=1024, n_heads=16,
                                     n_kv_heads=8, vocab=49155),
        "qwen2-vl-72b": dict(n_layers=80, d_model=8192, n_heads=64,
                             n_kv_heads=8, d_ff=29568, vocab=152064),
    }
    for arch, want in checks.items():
        cfg = get_config(arch)
        for k, v in want.items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"
    assert get_config("qwen3-moe-235b-a22b").moe.n_experts == 128
    assert get_config("qwen3-moe-235b-a22b").moe.top_k == 8
    assert get_config("granite-moe-1b-a400m").moe.n_experts == 32
    assert get_config("granite-moe-1b-a400m").moe.top_k == 8
    assert get_config("jamba-v0.1-52b").moe.n_experts == 16
    assert get_config("jamba-v0.1-52b").moe.top_k == 2
    assert get_config("gemma2-27b").sliding_window == 4096
    assert get_config("mamba2-130m").ssm.d_state == 128
