"""Seeded workload generators: determinism, validity, vmap over seeds."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import scenarios, simulate, workload

pytestmark = pytest.mark.tier1

KINDS = ("poisson", "diurnal", "bursty")


def _gen(key, kind, n=48, **kw):
    return workload.generate_cloudlets(
        key, n, kind=kind, rate=0.1, n_bursts=4, **kw)


@pytest.mark.parametrize("kind", KINDS)
def test_same_key_bit_identical(kind):
    a = _gen(jax.random.PRNGKey(3), kind)
    b = _gen(jax.random.PRNGKey(3), kind)
    for f in dataclasses.fields(a):
        np.testing.assert_array_equal(
            np.array(getattr(a, f.name)), np.array(getattr(b, f.name)),
            err_msg=f"Cloudlets.{f.name} not deterministic under {kind}")


@pytest.mark.parametrize("kind", KINDS)
def test_different_keys_differ(kind):
    a = _gen(jax.random.PRNGKey(0), kind)
    b = _gen(jax.random.PRNGKey(1), kind)
    assert not np.allclose(np.array(a.submit_t), np.array(b.submit_t))
    assert not np.allclose(np.array(a.length_mi), np.array(b.length_mi))


@pytest.mark.parametrize("kind", KINDS)
def test_generated_rows_valid(kind):
    cls = _gen(jax.random.PRNGKey(5), kind, io_mb=0.5)
    sub = np.array(cls.submit_t)
    assert (np.diff(sub) >= 0).all(), "rows must be sorted by submit_t"
    assert (sub >= 0).all()
    assert np.isfinite(sub).all()
    assert (np.array(cls.length_mi) > 0).all()
    assert (np.array(cls.input_mb) > 0).all()
    assert (np.array(cls.output_mb) > 0).all()
    assert np.array(cls.exists).all()


def test_routing_modes():
    rr = _gen(jax.random.PRNGKey(2), "poisson", n_vms=4)
    assert set(np.array(rr.vm)) <= {0, 1, 2, 3}
    svc = _gen(jax.random.PRNGKey(2), "poisson", n_vms=None)
    assert (np.array(svc.vm) == -1).all()


def test_poisson_mean_rate():
    """Arrival rate is statistically honest: n arrivals span ~ n/rate."""
    cls = workload.generate_cloudlets(
        jax.random.PRNGKey(11), 512, kind="poisson", rate=0.5)
    span = float(np.array(cls.submit_t)[-1])
    assert 0.8 * 512 / 0.5 < span < 1.25 * 512 / 0.5


def test_diurnal_modulation():
    """Arrivals cluster at the sinusoid peak: peak-phase bins hold more than
    trough-phase bins."""
    period = 200.0
    cls = workload.generate_cloudlets(
        jax.random.PRNGKey(13), 2048, kind="diurnal", rate=1.0,
        amp=0.9, period=period)
    t = np.array(cls.submit_t)
    phase = (t % period) / period
    peak = ((phase > 0.05) & (phase < 0.45)).sum()     # sin > 0 region
    trough = ((phase > 0.55) & (phase < 0.95)).sum()   # sin < 0 region
    assert peak > 1.5 * trough


def test_bursty_gaps_dominate():
    """On/off structure: the n_bursts-1 largest inter-arrival gaps are the
    off-gaps, far larger than the within-burst gaps."""
    cls = workload.generate_cloudlets(
        jax.random.PRNGKey(17), 64, kind="bursty", n_bursts=4, rate=1.0,
        off_gap_mean=500.0)
    gaps = np.sort(np.diff(np.array(cls.submit_t)))
    assert gaps[-3] > 10 * gaps[-4]


def test_vmap_over_32_seeds_valid_scenarios():
    """A seed campaign: 32 generated workloads in one vmap, all rows valid
    and pairwise distinct, and they simulate end to end."""
    keys = jax.random.split(jax.random.PRNGKey(21), 32)
    cls = jax.vmap(
        lambda k: workload.generate_cloudlets(
            k, 24, kind="bursty", n_bursts=3, rate=0.2, off_gap_mean=300.0,
            median_mi=20_000.0, n_vms=4)
    )(keys)
    sub = np.array(cls.submit_t)
    assert sub.shape == (32, 24)
    assert (np.diff(sub, axis=1) >= 0).all()
    assert np.isfinite(sub).all()
    assert len({tuple(row) for row in sub.round(4).tolist()}) == 32

    from repro.core import broadcast_campaign, run_campaign

    template = scenarios.generated_scenario(
        keys[0], kind="bursty", n_cloudlets=24, n_vms=4, n_hosts=4,
        rate=0.2, n_bursts=3, off_gap_mean=300.0, median_mi=20_000.0)
    batched = broadcast_campaign(template, 32, cloudlets=cls)
    res = run_campaign(batched)
    assert (np.array(res.n_finished) == 24).all()


def test_generated_scenario_simulates():
    for kind in KINDS:
        scn = scenarios.generated_scenario(
            jax.random.PRNGKey(8), kind=kind, n_cloudlets=16, n_vms=4,
            n_hosts=4, rate=0.2, n_bursts=4, median_mi=10_000.0)
        res = jax.jit(simulate)(scn)
        assert int(res.n_finished) == 16, kind
