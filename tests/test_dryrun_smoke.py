"""Multi-device correctness + dry-run smoke, via a 4-device subprocess
(XLA_FLAGS must be set before jax init, so these run out of process)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_moe_shard_map_matches_local():
    """The explicit EP schedule == the single-device reference path."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.models import build_model, moe
from repro.models import moe as moe_mod
from repro.dist.act_sharding import activation_shardings

cfg = get_config('granite-moe-1b-a400m', smoke=True)
m = build_model(cfg)
params = m.init(jax.random.PRNGKey(0))
mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
pp = jax.tree.map(lambda a: a[0], params['periods'])['sub0']['mlp']

y_local, aux_local = jax.jit(lambda p, x: moe_mod._moe_local(p, cfg, x))(pp, x)
with mesh, activation_shardings(mesh):
    y_sm, aux_sm = jax.jit(lambda p, x: moe_mod.moe_apply(p, cfg, x))(pp, x)
err = float(jnp.max(jnp.abs(y_local - y_sm)))
aerr = abs(float(aux_local) - float(aux_sm))
print("ERR", err, aerr)
assert err < 2e-4, err
assert aerr < 1e-4, aerr
""")
    assert "ERR" in out


def test_flash_decode_length_sharded_matches_local():
    """attention_decode's flash-decoding path (KV cache sharded on LENGTH
    because the kv-head count doesn't divide tp) == plain decode."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.models import build_model
from repro.dist.act_sharding import activation_shardings

cfg = get_config('internlm2-1.8b', smoke=True)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
B, S, L = 4, 8, 16
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab, jnp.int32)
lg, caches = model.prefill(params, {"tokens": toks[:, :S-2]}, L)
pos = jnp.full((B,), S-2, jnp.int32)
lg1, caches1 = model.decode_step(params, caches, toks[:, S-2][:, None], pos)

mesh = Mesh(np.array(jax.devices()).reshape(1, 4), ("data", "model"))
# ntp=4: kv heads (2) don't divide, cache length (16) does -> flash path
with mesh, activation_shardings(mesh):
    lgS, cachesS = jax.jit(model.decode_step)(
        params, caches, toks[:, S-2][:, None], pos)
err = float(jnp.max(jnp.abs(lg1 - lgS)))
ck = float(jnp.max(jnp.abs(caches1['sub0']['k'] - cachesS['sub0']['k'])))
print("ERR", err, ck)
assert err < 2e-3, err
assert ck < 1e-5, ck
""")
    assert "ERR" in out


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "granite-moe-1b-a400m",
                                  "mamba2-130m"])
def test_tiny_mesh_train_step_lowers(arch):
    """lower+compile the real train step on a 2x2 mesh with smoke configs —
    the in-process analogue of the 512-device dry-run."""
    out = _run(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.models import build_model
from repro.dist import param_pspec_tree, named
from repro.dist.act_sharding import activation_shardings
from repro.train import OptConfig, adamw_init, make_train_step

cfg = get_config('{arch}', smoke=True)
model = build_model(cfg)
mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
pspecs = param_pspec_tree(pshapes, mesh)
psh = named(mesh, pspecs)
step = make_train_step(model, OptConfig(), microbatches=2, param_shardings=psh)
batch = {{
    "tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
    "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32),
}}
opt_shape = jax.eval_shape(adamw_init, pshapes)
with mesh, activation_shardings(mesh):
    lowered = jax.jit(step).lower(pshapes, opt_shape, batch)
compiled = lowered.compile()
print("COMPILED", compiled.memory_analysis().temp_size_in_bytes)
""")
    assert "COMPILED" in out


def test_real_execution_on_mesh():
    """Actually EXECUTE a sharded train step on 4 devices and compare the
    loss against single-device execution."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.models import build_model
from repro.dist import param_pspec_tree, named
from repro.dist.act_sharding import activation_shardings
from repro.train import OptConfig, adamw_init, make_train_step

cfg = get_config('qwen3-32b', smoke=True)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = adamw_init(params)
batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab, jnp.int32),
    "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab, jnp.int32),
}
# single device
step1 = jax.jit(make_train_step(model, OptConfig()))
_, _, m1 = step1(params, opt, batch)

mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
psh = named(mesh, param_pspec_tree(jax.eval_shape(lambda: params), mesh))
stepN = jax.jit(make_train_step(model, OptConfig(), param_shardings=psh),
                in_shardings=(psh, None, None))
with mesh, activation_shardings(mesh):
    _, _, mN = stepN(jax.device_put(params, psh), opt, batch)
print("LOSSES", float(m1["loss"]), float(mN["loss"]))
assert abs(float(m1["loss"]) - float(mN["loss"])) < 1e-3
""")
    assert "LOSSES" in out
