"""Runtime (live) VM migration (DESIGN.md §8): consolidation + balance
semantics, progress preservation, determinism, and vmapped threshold-grid
campaigns row-matching a Python loop of single runs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    broadcast_campaign,
    run_campaign,
    scenarios,
    simulate,
    simulate_instrumented,
)

pytestmark = pytest.mark.tier1


def _no_live(scn):
    return scn.replace(
        policy=scn.policy.replace(live_migration=jnp.asarray(False)))


def _run_instrumented(scn):
    # private jit target: jax.jit caches per underlying function object, so
    # jitting simulate_instrumented directly would pollute the cache-size
    # assertions other test modules make about their own wrappers
    return simulate_instrumented(scn)


def test_consolidation_saves_idle_energy():
    """THE demo (ISSUE acceptance): live migration drains DC1's idle VMs
    into DC0's spare slots, the emptied hosts power-gate, and energy drops
    measurably vs the no-migration control — same compiled program (the
    flag is traced), zero lost cloudlets, identical end time."""
    fn = jax.jit(_run_instrumented)
    res_on, out_on = fn(scenarios.consolidation_scenario())
    res_off, out_off = fn(_no_live(scenarios.consolidation_scenario()))
    assert fn._cache_size() == 1, "on/off must share one compilation"
    n_cl = scenarios.consolidation_scenario().cloudlets.n_cloudlets
    assert int(res_on.n_finished) == int(res_off.n_finished) == n_cl
    assert int(res_on.n_migrations) == 4          # all 4 spare images moved
    assert int(res_off.n_migrations) == 0
    assert int(out_on["migration"]["n_consolidate"]) == 4
    assert int(out_on["migration"]["n_balance"]) == 0
    # identical work => identical end time; energy is the only divergence
    assert float(res_on.end_t) == float(res_off.end_t)
    e_on = float(np.sum(np.array(res_on.energy_j)))
    e_off = float(np.sum(np.array(res_off.energy_j)))
    assert e_on < 0.5 * e_off, (e_on, e_off)
    # the drained DC's hosts are empty: every VM ends at DC0
    assert (np.array(res_on.vm_dc) == 0).all()
    # the image transfers hit the inter-DC bandwidth meter at the destination
    assert float(np.array(res_on.bw_cost)[0]) > float(
        np.array(res_off.bw_cost)[0])


def test_balance_move_preserves_progress():
    """A worker VM migrates mid-execution: its cloudlet keeps the 50k MI it
    accrued before the move and finishes exactly one transfer-window later
    than its stay-at-home twin — stop-and-copy, not restart."""
    scn = scenarios.balance_scenario()
    res, out = jax.jit(_run_instrumented)(scn)
    assert int(res.n_finished) == 3
    assert int(res.n_migrations) == 1
    assert int(out["migration"]["n_balance"]) == 1
    fin = np.array(res.finish_t)
    # tick at t=100: both workers hold 950k MI. The migrant stalls for
    # 30 + 1024/100 s then runs at full speed; its twin runs from t=100.
    transfer = 30.0 + 1024.0 / 100.0
    np.testing.assert_allclose(fin[2], 100.0 + 950.0, atol=1.0)
    np.testing.assert_allclose(fin[1], 100.0 + transfer + 950.0, atol=1.0)
    # restart-from-zero would land ~1140s later; preserved progress wins
    ctrl = jax.jit(simulate)(_no_live(scenarios.balance_scenario()))
    assert float(res.makespan) < 0.6 * float(ctrl.makespan)
    assert int(ctrl.n_migrations) == 0


def test_balance_improvement_rule_prevents_ping_pong():
    """A lone busy VM never bounces between two idle DCs: moving it cannot
    shrink the utilization spread, so the improvement rule vetoes it."""
    scn = scenarios.balance_scenario(balance_thresh=0.5, bg_mi=1.0)
    # make DC0 hold ONE worker: drop the second worker's cloudlet
    cls = scn.cloudlets.replace(
        exists=jnp.asarray(np.array([True, True, False])))
    res = jax.jit(simulate)(scn.replace(cloudlets=cls))
    # util(DC0)=1.0 > 0.5 with an empty feasible peer, yet no move happens
    assert int(res.n_migrations) == 0
    assert int(res.n_finished) == 2


def test_migration_requires_federation():
    """Live migration is a CloudCoordinator policy: with federation off the
    thresholds may scream but n_migrations stays 0."""
    scn = scenarios.consolidation_scenario()
    scn = scn.replace(policy=scn.policy.replace(
        federation=jnp.asarray(False)))
    res = jax.jit(simulate)(scn)
    assert int(res.n_migrations) == 0
    assert int(res.n_finished) == scn.cloudlets.n_cloudlets


def test_same_scenario_bit_identical():
    """Same key/threshold ⇒ bit-identical SimResult, field by field."""
    fn = jax.jit(simulate)
    a = fn(scenarios.consolidation_scenario())
    b = fn(scenarios.consolidation_scenario())
    for f in dataclasses.fields(a):
        np.testing.assert_array_equal(
            np.array(getattr(a, f.name)), np.array(getattr(b, f.name)),
            err_msg=f"SimResult.{f.name} not deterministic")


def test_vmapped_threshold_grid_matches_loop():
    """A vmapped consolidate-threshold grid row-matches a Python loop of
    single runs (mirrors test_workload.py's seed-campaign pattern): integer
    and boolean fields exactly, floats to tight tolerance — and the
    thresholds bite (0 disables, high values drain the spare DC)."""
    template = scenarios.consolidation_scenario()
    K = 6
    threshs = jnp.linspace(0.0, 0.9, K)
    pol = jax.vmap(
        lambda u: template.policy.replace(migrate_consolidate_thresh=u)
    )(threshs)
    batched = broadcast_campaign(template, K, policy=pol)
    res = run_campaign(batched)

    fn = jax.jit(simulate)
    singles = [
        fn(template.replace(policy=template.policy.replace(
            migrate_consolidate_thresh=threshs[i])))
        for i in range(K)
    ]
    for f in dataclasses.fields(res):
        got = np.array(getattr(res, f.name))
        want = np.stack([np.array(getattr(s, f.name)) for s in singles])
        if got.dtype.kind in "biu":
            np.testing.assert_array_equal(
                got, want, err_msg=f"SimResult.{f.name} grid != loop")
        else:
            np.testing.assert_allclose(
                got, want, rtol=1e-5, atol=1e-3,
                err_msg=f"SimResult.{f.name} grid != loop")
    n_mig = np.array(res.n_migrations)
    assert n_mig[0] == 0, "threshold 0 must disable consolidation"
    assert (n_mig[1:] == 4).all(), "positive thresholds drain the spare DC"
    assert (np.array(res.n_finished) == template.cloudlets.n_cloudlets).all()


def test_table1_live_migration_knob():
    """The knob on the existing federation builder attaches the instrument
    and leaves the published Table-1 numbers untouched when off."""
    base = jax.jit(simulate)(scenarios.table1_scenario(True))
    knob_off = scenarios.table1_scenario(True, live_migration=True)
    knob_off = _no_live(knob_off)
    res = jax.jit(simulate)(knob_off)
    # instrument attached but gated off: same federation outcome
    assert int(res.n_migrations) == int(base.n_migrations) == 10
    np.testing.assert_allclose(
        float(res.mean_turnaround), float(base.mean_turnaround), rtol=1e-6)
