"""Training substrate: optimizer math, microbatch equivalence, learning."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import ShardedLoader
from repro.models import build_model
from repro.train import OptConfig, adamw_init, make_train_step


def test_microbatch_equivalence():
    """microbatches=1 and =4 give (near-)identical updates."""
    cfg = get_config("internlm2-1.8b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                     cfg.vocab, jnp.int32),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                                     cfg.vocab, jnp.int32),
    }
    outs = {}
    for mb in (1, 4):
        step = jax.jit(make_train_step(model, opt_cfg, microbatches=mb))
        p, o, m = step(params, adamw_init(params), batch)
        outs[mb] = (p, float(m["loss"]))
    assert np.isclose(outs[1][1], outs[4][1], rtol=1e-4)
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.array(a), np.array(b), atol=2e-5,
                                   rtol=2e-4)


def test_loss_decreases_markov_task():
    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptConfig(lr=2e-3, warmup_steps=3, total_steps=30)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, opt_cfg))
    loader = ShardedLoader(cfg.vocab, 8, 48, seed=1)
    losses = []
    for _, batch in zip(range(25), loader):
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step(params, opt, jb)
        losses.append(float(m["loss"]))
    loader.close()
    assert losses[-1] < losses[0] - 0.3, losses[::6]


def test_grad_clip_and_schedule():
    from repro.train import cosine_schedule, global_norm

    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                    min_lr_ratio=0.1)
    lr = cosine_schedule(cfg)
    assert float(lr(0)) == 0.0
    assert np.isclose(float(lr(10)), 1.0, rtol=1e-5)
    assert np.isclose(float(lr(110)), 0.1, rtol=1e-3)
    assert np.isclose(float(lr(60)), 0.55, rtol=1e-2)  # cosine midpoint
    tree = {"a": jnp.full((3,), 2.0), "b": jnp.full((4,), -1.0)}
    assert np.isclose(float(global_norm(tree)), np.sqrt(12 + 4))
