"""Simulation-invariant suite: property-style conservation checks swept over
every scenario constructor in ``scenarios.py``.

Four families, each phrased against the public Instrument/driver surface so
they hold for *any* engine change, not one code path:

* **work conservation** — integrating the piecewise-constant rates over the
  emitted events reproduces each cloudlet's depleted work *plus* whatever
  checkpoint rollbacks re-queued (``SimState.cl_rollback_mi`` — zero without
  failures, so the classic equality is the special case); finished rows
  integrate to their full ``length_mi`` + re-done work (within the engine's
  documented float32 finish tolerance).
* **capacity** — granted host MIPS never exceeds host capacity at any event,
  and the free-resource ledgers (RAM/storage/bandwidth — cores too under
  ``core_reserving``) never go negative — including through failure
  revocation and re-placement (DESIGN.md §9).
* **time** — event times are non-decreasing with non-negative intervals
  (``simulate_history`` rows).
* **federation gate** — ``n_migrations == 0`` whenever federation is off.
* **reliability gate** — ``n_evacuations == 0`` and ``downtime == 0``
  whenever the outage schedule is all-INF padding (MTBF = ∞).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SPACE_SHARED,
    TIME_SHARED,
    scenarios,
    simulate,
    simulate_history,
    simulate_instrumented,
    step,
)
from repro.core import energy as energy_mod
from repro.core.pytree import pytree_dataclass

pytestmark = pytest.mark.tier1


def _all_scenarios():
    """One small instance per scenario constructor in scenarios.py."""
    key = jax.random.PRNGKey(0)
    return [
        ("fig4_ss", scenarios.fig4_scenario(SPACE_SHARED, SPACE_SHARED)),
        ("fig4_tt", scenarios.fig4_scenario(TIME_SHARED, TIME_SHARED)),
        ("fig7_8", scenarios.fig7_8_scenario(32)),
        ("fig9_10", scenarios.fig9_10_scenario(
            TIME_SHARED, n_hosts=40, n_vms=4, n_groups=2)),
        ("table1_fed", scenarios.table1_scenario(True)),
        ("table1_nofed", scenarios.table1_scenario(False)),
        ("generated", scenarios.generated_scenario(
            key, kind="poisson", n_cloudlets=16, n_vms=4, n_hosts=4,
            rate=0.2, median_mi=10_000.0)),
        ("autoscale", scenarios.autoscale_scenario(
            key, scale_down_thresh=0.05)),
        ("consolidation", scenarios.consolidation_scenario()),
        ("balance", scenarios.balance_scenario()),
        ("reliability", scenarios.reliability_scenario(
            key, evacuation=True, ckpt_interval=25_000.0)),
        ("reliability_inf", scenarios.reliability_scenario(
            None, evacuation=True)),
        ("evacuation", scenarios.evacuation_scenario()),
        ("evacuation_ctrl", scenarios.evacuation_scenario(
            evacuation=False, ckpt_interval=3.0e38)),
        ("staging", scenarios.staging_scenario(n_cloudlets=24)),
        ("staging_loc", scenarios.staging_scenario(
            n_cloudlets=24, locality_dispatch=True)),
    ]


_IDS = [name for name, _ in _all_scenarios()]


def _run_instrumented(scn, extra):
    # private jit target: jax.jit caches per underlying function object, so
    # jitting simulate_instrumented directly would pollute the cache-size
    # assertions other test modules make about their own wrappers
    return simulate_instrumented(scn, extra)


@pytree_dataclass
class _ConservationInstrument(step.Instrument):
    """Per-cloudlet integral of rate·dt over the emitted events."""

    name = "conservation"

    def init(self, scn):
        return jnp.zeros((scn.cloudlets.n_cloudlets,), jnp.float32)

    def post(self, scn, st, ev, aux):
        return st, aux + jnp.where(ev.active, ev.rate * ev.dt, 0.0)

    def finalize(self, scn, st, aux):
        return {
            "executed_mi": aux,
            "rem_mi": st.rem_mi,
            "rollback_mi": st.cl_rollback_mi,
        }


@pytree_dataclass
class _CapacityInstrument(step.Instrument):
    """Worst-case (over events) host over-grant and ledger undershoot."""

    name = "capacity"

    def init(self, scn):
        z = jnp.asarray(0.0, jnp.float32)
        return (z, z, z)  # max over-grant, min free resource, min free cores

    def post(self, scn, st, ev, aux):
        over, min_free, min_cores = aux
        granted = energy_mod.host_granted_mips(scn, st, vm_mips=ev.vm_mips)
        cap = scn.hosts.cores.astype(jnp.float32) * scn.hosts.mips
        over = jnp.maximum(
            over,
            jnp.max(jnp.where(scn.hosts.exists, granted - cap, -jnp.inf)),
        )
        free = jnp.minimum(
            jnp.minimum(jnp.min(st.free_ram), jnp.min(st.free_storage)),
            jnp.min(st.free_bw),
        )
        return st, (
            over,
            jnp.minimum(min_free, free),
            jnp.minimum(min_cores, jnp.min(st.free_cores)),
        )

    def finalize(self, scn, st, aux):
        return {
            "max_over_grant": aux[0],
            "min_free": aux[1],
            "min_free_cores": aux[2],
        }


@pytest.mark.parametrize("name,scn", _all_scenarios(), ids=_IDS)
def test_conservation_and_capacity(name, scn):
    res, out = jax.jit(_run_instrumented)(
        scn, (_ConservationInstrument(), _CapacityInstrument()))

    # --- work conservation (modulo rollback): integral of rates ==
    #     depleted work + MI re-queued by failure rollbacks (exactly zero
    #     for every scenario without an outage schedule) ---
    executed = np.array(out["conservation"]["executed_mi"])
    rem = np.array(out["conservation"]["rem_mi"])
    rollback = np.array(out["conservation"]["rollback_mi"])
    length = np.array(scn.cloudlets.length_mi)
    exists = np.array(scn.cloudlets.exists)
    if scn.outages is None:
        assert (rollback == 0).all(), f"{name}: rollback without outages"
    assert (rollback >= 0).all(), f"{name}: negative rollback"
    np.testing.assert_allclose(
        executed[exists], (length - rem + rollback)[exists],
        rtol=1e-4, atol=1.0,
        err_msg=f"{name}: rate·dt integral != depleted + rolled-back work")
    fin = np.isfinite(np.array(res.finish_t)) & (
        np.array(res.finish_t) < 1e30)
    # finished rows executed their full submitted work plus whatever the
    # rollbacks made them re-do (within the engine's documented finish
    # tolerance, step._eps_mi)
    np.testing.assert_allclose(
        executed[fin], (length + rollback)[fin], rtol=2e-3, atol=1.0,
        err_msg=f"{name}: finished cloudlets lost work")

    # --- capacity: grants bounded, ledgers non-negative ---
    assert float(out["capacity"]["max_over_grant"]) <= 0.5, name
    assert float(out["capacity"]["min_free"]) >= -1e-3, name
    if bool(scn.policy.core_reserving):
        assert float(out["capacity"]["min_free_cores"]) >= -1e-3, name

    # --- federation gate ---
    if not bool(scn.policy.federation):
        assert int(res.n_migrations) == 0, name


@pytest.mark.parametrize(
    "name,scn",
    [s for s in _all_scenarios()
     if s[0] in ("fig4_ss", "table1_fed", "autoscale", "consolidation",
                 "reliability", "evacuation")],
    ids=["fig4_ss", "table1_fed", "autoscale", "consolidation",
         "reliability", "evacuation"],
)
def test_event_times_monotone(name, scn):
    res, hist = jax.jit(simulate_history)(scn)
    v = np.array(hist.valid)
    t = np.array(hist.t)[v]
    dt = np.array(hist.dt)[v]
    assert (dt >= 0).all(), name
    assert (np.diff(t) >= -1e-6).all(), name
    assert int(res.n_events) == int(v.sum()), name


@pytest.mark.parametrize("name,scn", _all_scenarios(), ids=_IDS)
def test_no_migrations_with_federation_off(name, scn):
    """Forcing the traced federation flag off zeroes migrations everywhere —
    creation-time overflow, the live MigrationInstrument, and proactive
    evacuation alike."""
    scn = scn.replace(policy=scn.policy.replace(
        federation=jnp.asarray(False)))
    res = jax.jit(simulate)(scn)
    assert int(res.n_migrations) == 0, name
    assert int(res.n_evacuations) == 0, name


def _neutral_topology_scenarios():
    """Scenarios where no two transfers ever share a link: the regime where
    attaching a *neutral* topology (uniform bandwidth equal to the flat
    ``interdc_bw_mbps`` divisor, zero latency) must be bitwise invisible.
    Contended scenarios are excluded by design — fair sharing on a shared
    link is exactly the behavior the ledger is meant to change
    (tests/test_network.py pins those numbers)."""
    key = jax.random.PRNGKey(0)
    return [
        ("fig4_ss", scenarios.fig4_scenario(SPACE_SHARED, SPACE_SHARED)),
        ("fig4_tt", scenarios.fig4_scenario(TIME_SHARED, TIME_SHARED)),
        ("fig7_8", scenarios.fig7_8_scenario(16)),
        ("generated", scenarios.generated_scenario(
            key, kind="poisson", n_cloudlets=16, n_vms=4, n_hosts=4,
            rate=0.2, median_mi=10_000.0)),
        ("single_overflow", scenarios.table1_scenario(True, n_vms=8)),
        ("balance", scenarios.balance_scenario()),
        # consolidation_scenario is intentionally absent: its sensor tick
        # commits two live migrations over one link in the same event, so
        # the fair-share recompute (correctly) diverges from the flat path
    ]


_NEUTRAL_IDS = [name for name, _ in _neutral_topology_scenarios()]


@pytest.mark.parametrize(
    "name,scn", _neutral_topology_scenarios(), ids=_NEUTRAL_IDS)
def test_neutral_topology_is_bitwise_flat(name, scn):
    """The topology-vs-flat equivalence lock (DESIGN.md §13): a uniform
    topology with ``bw_mbps == Policy.interdc_bw_mbps`` and zero latency
    yields a bit-identical ``SimResult`` to ``topology=None`` — through the
    plain, traced, and batch-major drivers."""
    import dataclasses

    from repro.core import simulate_trace, stack_scenarios

    topo = energy_mod.Topology.uniform(
        scn.hosts.n_dc, latency_s=0.0,
        bw_mbps=float(scn.policy.interdc_bw_mbps))
    scn_t = scn.replace(topology=topo)
    res = jax.jit(simulate)(scn)
    res_t = jax.jit(simulate)(scn_t)
    for f in dataclasses.fields(res):
        np.testing.assert_array_equal(
            np.array(getattr(res, f.name)), np.array(getattr(res_t, f.name)),
            err_msg=f"{name}: SimResult.{f.name} diverged (plain)")
    ts = jnp.asarray(np.arange(0.0, 3000.0, 401.0, dtype=np.float32))
    res_tr, _ = simulate_trace(scn_t, ts)
    for f in dataclasses.fields(res):
        np.testing.assert_array_equal(
            np.array(getattr(res, f.name)),
            np.array(getattr(res_tr, f.name)),
            err_msg=f"{name}: SimResult.{f.name} diverged (trace)")
    res_b = jax.jit(simulate)(stack_scenarios([scn_t, scn_t]))
    for f in dataclasses.fields(res):
        np.testing.assert_array_equal(
            np.array(getattr(res, f.name)),
            np.array(getattr(res_b, f.name))[0],
            err_msg=f"{name}: SimResult.{f.name} diverged (batch-major)")


@pytest.mark.parametrize("name,scn", _all_scenarios(), ids=_IDS)
def test_no_failures_without_outage_windows(name, scn):
    """MTBF = ∞ (an all-INF schedule — or no schedule at all) means the
    reliability subsystem never fires: no evacuations, no downtime, no
    rollback, even with the evacuation policy armed."""
    if scn.outages is not None and bool(
            np.any(np.array(scn.outages.fail_t) < 1e30)):
        pytest.skip("scenario schedules real outages")
    res, out = jax.jit(_run_instrumented)(scn, (_ConservationInstrument(),))
    assert int(res.n_evacuations) == 0, name
    assert float(res.downtime) == 0.0, name
    assert (np.array(out["conservation"]["rollback_mi"]) == 0).all(), name
