"""Sharding rules: validity, divisibility fallbacks, memory model."""
import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models import TRAIN_4K, DECODE_32K, build_model
from repro.dist import param_pspec_tree, input_pspec_tree


def _fake_mesh(shape, axes):
    """Abstract mesh for spec derivation only (no real devices needed)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(shape, axes)          # jax >= 0.5
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))  # jax 0.4.x: (name, size) pairs


MESH = _fake_mesh((16, 16), ("data", "model"))


def _check_specs(shapes, specs, mesh):
    for leaf, spec in zip(
        jax.tree.leaves(shapes),
        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)),
    ):
        assert len(spec) <= len(leaf.shape)
        used = set()
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            for a in axes:
                assert a not in used, f"axis {a} reused in {spec}"
                used.add(a)
            total = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % total == 0, (
                f"dim {dim} not divisible by {axes} ({total}) in {spec}")


def test_param_specs_all_archs():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = param_pspec_tree(shapes, MESH)
        _check_specs(shapes, specs, MESH)


def test_input_specs_all_archs():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        for cell in (TRAIN_4K, DECODE_32K):
            specs = model.input_specs(cell)
            pspecs = input_pspec_tree(specs, MESH)
            _check_specs(specs, pspecs, MESH)


def test_whisper_vocab_fallback():
    """51866 is not 16-divisible: embed must not shard V over model."""
    cfg = get_config("whisper-large-v3")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_pspec_tree(shapes, MESH)
    assert specs["embed"][0] is None


def test_moe_expert_sharding():
    cfg = get_config("qwen3-moe-235b-a22b")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_pspec_tree(shapes, MESH)
    wg = specs["periods"]["sub0"]["mlp"]["w_gate"]
    assert wg == P(None, "model", None, "data")  # (layers, E, D, F)
