"""simlint: every rule must (a) pass on the current tree and (b) FIRE on a
hand-built violating program — a linter whose rules never trip is just a
slow no-op, so each rule gets a negative control:

  R1  a vmapped cond (XLA flattens it to select) and a scope-free program
  R2  an undonated chunk runner (empty alias table)
  R3  an instrument hook calling ``jax.debug.callback``
  R4  data-dependent slice widths / mismatched batch leaf ranks
  R5  an entry whose static argument forks the jit cache
  R6  doctored kernel plans (non-pow2 block, split row, wrong SMEM shapes)

The positive (tree-is-clean) checks run the cheap rules directly; the full
six-rule sweep over all entry points is the CI ``scripts/simlint.py`` step,
not a unit test.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import simlint
from repro.core import step
from repro.kernels import ops, vm_update

pytestmark = pytest.mark.tier1


def _errors(findings):
    return [f for f in findings if f.severity == "error"]


# ---------------------------------------------------------------------------
# R1 cond-not-select
# ---------------------------------------------------------------------------


def _hlo_of(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestR1CondNotSelect:
    def test_scalar_cond_passes(self):
        def good(x, flag):
            with jax.named_scope(step.SCOPE_PROVISION):
                return jax.lax.cond(
                    flag, lambda v: jnp.dot(v, v), lambda v: v, x
                )

        hlo = _hlo_of(good, jnp.ones((8, 8)), jnp.bool_(True))
        assert simlint.check_cond_not_select(
            hlo, [step.SCOPE_PROVISION], "t"
        ) == []

    def test_vmapped_cond_trips(self):
        # vmap over the predicate forces both branches -> select, the exact
        # degradation R1 exists to catch
        def bad(x, flag):
            with jax.named_scope(step.SCOPE_PROVISION):
                return jax.lax.cond(
                    flag, lambda v: v * 2.0, lambda v: v, x
                )

        hlo = _hlo_of(
            jax.vmap(bad), jnp.ones((4, 8)), jnp.ones((4,), bool)
        )
        errs = _errors(simlint.check_cond_not_select(
            hlo, [step.SCOPE_PROVISION], "t"
        ))
        assert len(errs) == 1
        assert "select" in errs[0].message
        assert errs[0].rule == "R1" and errs[0].entry_point == "t"

    def test_missing_scope_trips(self):
        hlo = _hlo_of(lambda x: x + 1.0, jnp.ones((4,)))
        errs = _errors(simlint.check_cond_not_select(
            hlo, [step.SCOPE_PROVISION, step.SCOPE_DISPATCH], "t"
        ))
        assert len(errs) == 2
        assert all("not found" in e.message for e in errs)


# ---------------------------------------------------------------------------
# R2 donation-aliases
# ---------------------------------------------------------------------------


class TestR2DonationAliases:
    @staticmethod
    def _lower(donate: bool):
        def runner(xs):
            return jax.tree.map(lambda x: x * 2.0 + 1.0, xs)

        f = jax.jit(runner, donate_argnums=(0,) if donate else ())
        args = ({"a": jnp.ones((64,)), "b": jnp.ones((32, 2))},)
        return f.lower(*args).compile().as_text()

    def test_donated_runner_passes(self):
        hlo = self._lower(donate=True)
        assert simlint.check_donation_aliases(hlo, 2, "t") == []

    def test_undonated_runner_trips(self):
        # the PR-2 regression class: runner "donates" nothing, alias table
        # empty, campaigns silently pay double memory
        hlo = self._lower(donate=False)
        errs = _errors(simlint.check_donation_aliases(hlo, 2, "t"))
        assert len(errs) == 1
        assert "0 of 2" in errs[0].message and errs[0].rule == "R2"

    def test_partial_coverage_warns_not_errors(self):
        hlo = self._lower(donate=True)
        out = simlint.check_donation_aliases(hlo, 3, "t")
        assert _errors(out) == []
        assert [f.severity for f in out] == ["warning"]

    def test_zero_donatable_is_error(self):
        errs = _errors(simlint.check_donation_aliases("HloModule m", 0, "t"))
        assert len(errs) == 1 and "no donatable" in errs[0].message


# ---------------------------------------------------------------------------
# R3 pure-observer
# ---------------------------------------------------------------------------


class TestR3PureObserver:
    def test_pure_hook_passes(self):
        cj = jax.make_jaxpr(lambda s: (s * 2.0, jnp.sum(s)))(jnp.ones((4,)))
        assert simlint.check_effects(cj, "t") == []

    def test_debug_callback_instrument_trips(self):
        # a "logging" instrument hook — the classic way to break the
        # bitwise trace-equivalence contract
        def noisy_post(st):
            jax.debug.callback(lambda v: None, st)
            return st

        cj = jax.make_jaxpr(noisy_post)(jnp.ones((4,)))
        errs = _errors(simlint.check_effects(cj, "instrument:noisy.post"))
        assert errs, "debug_callback hook must trip R3"
        assert errs[0].rule == "R3"
        assert errs[0].entry_point == "instrument:noisy.post"

    def test_debug_print_trips(self):
        def chatty(x):
            jax.debug.print("x={x}", x=x)
            return x + 1.0

        cj = jax.make_jaxpr(chatty)(jnp.float32(0.0))
        assert _errors(simlint.check_effects(cj, "t"))


# ---------------------------------------------------------------------------
# R4 shape-stable-scan
# ---------------------------------------------------------------------------


class TestR4ShapeStable:
    def test_static_program_passes(self):
        cj = jax.make_jaxpr(
            lambda x: jax.lax.dynamic_slice(x, (jnp.int32(1),), (3,))
        )(jnp.arange(8.0))
        assert simlint.check_shape_stability(cj, "t") == []

    def test_rank_consistency_passes_on_true_batch(self):
        single = {"a": (8,), "b": ()}
        batch = {"a": (4, 8), "b": (4,)}
        assert simlint.check_rank_consistency(single, batch, 4, "t") == []

    def test_rank_mismatch_trips(self):
        single = {"a": (8,), "b": ()}
        batch = {"a": (4, 8), "b": (2,)}  # wrong batch dim on b
        errs = _errors(
            simlint.check_rank_consistency(single, batch, 4, "t")
        )
        assert len(errs) == 1 and "b" in errs[0].message

    def test_leaf_set_drift_trips(self):
        errs = _errors(simlint.check_rank_consistency(
            {"a": (8,), "gone": ()}, {"a": (4, 8), "new": (4,)}, 4, "t"
        ))
        assert {("gone" in e.message) or ("new" in e.message)
                for e in errs} == {True}
        assert len(errs) == 2


# ---------------------------------------------------------------------------
# R5 recompile-hazard
# ---------------------------------------------------------------------------


class TestR5RecompileHazard:
    def test_traced_knob_passes(self):
        f = jax.jit(lambda x, k: x * k)
        f(jnp.ones((4,)), jnp.float32(2.0))
        f(jnp.ones((4,)), jnp.float32(3.0))
        assert simlint.check_one_compilation(f, 2, "t") == []

    def test_static_knob_forks_cache_and_trips(self):
        # a policy knob accidentally made static: every swept value is a
        # fresh XLA compile — the hazard R5 guards the engine against
        f = jax.jit(lambda x, k: x * k, static_argnums=(1,))
        f(jnp.ones((4,)), 2.0)
        f(jnp.ones((4,)), 3.0)
        errs = _errors(simlint.check_one_compilation(f, 2, "t"))
        assert len(errs) == 1
        assert "2 compilations" in errs[0].message
        assert errs[0].rule == "R5"


# ---------------------------------------------------------------------------
# R6 kernel-budget
# ---------------------------------------------------------------------------


class TestR6KernelBudget:
    @pytest.mark.parametrize("c", [1, 96, 128, 1000, 4096, 3 << 17])
    def test_real_plans_pass(self, c):
        plan = vm_update.kernel_plan(4, c, ops.advance_block(c))
        assert simlint.check_kernel_plan(
            plan, c, ops._MAX_BLOCK, "t"
        ) == []

    def test_non_pow2_block_trips(self):
        plan = vm_update.kernel_plan(4, 192, 192)
        errs = _errors(simlint.check_kernel_plan(plan, 192, 1 << 17, "t"))
        assert any("power of two" in e.message for e in errs)

    def test_sub_floor_block_trips(self):
        plan = vm_update.kernel_plan(4, 64, 64)
        errs = _errors(simlint.check_kernel_plan(plan, 64, 1 << 17, "t"))
        assert any("128-lane floor" in e.message for e in errs)

    def test_over_cap_block_trips(self):
        big = 1 << 18
        plan = vm_update.kernel_plan(4, big, big)
        errs = _errors(simlint.check_kernel_plan(plan, big, 1 << 17, "t"))
        assert any("VMEM cap" in e.message for e in errs)

    def test_split_row_that_fits_trips(self):
        # block 128 on a 256-wide row that would fit a 256 tile: the fused
        # single-pass path was forfeited for no reason
        plan = vm_update.kernel_plan(4, 256, 128)
        errs = _errors(simlint.check_kernel_plan(plan, 256, 1 << 17, "t"))
        assert any("splits a row" in e.message for e in errs)

    def test_doctored_smem_shape_trips(self):
        plan = vm_update.kernel_plan(4, 128, 128)
        plan["smem_out"] = (("dt", (4, 1)),)
        errs = _errors(simlint.check_kernel_plan(plan, 128, 1 << 17, "t"))
        assert any("scalars-per-row" in e.message for e in errs)

    def test_doctored_variant_trips(self):
        plan = vm_update.kernel_plan(4, 128, 128)
        plan["variant"], plan["grid"] = "two_phase", (4, 2, 1)
        errs = _errors(simlint.check_kernel_plan(plan, 128, 1 << 17, "t"))
        assert any("implies 'fused'" in e.message for e in errs)

    def test_fused_scratch_trips(self):
        plan = vm_update.kernel_plan(4, 128, 128)
        plan["smem_scratch"] = (("min_sc", (1,)),)
        errs = _errors(simlint.check_kernel_plan(plan, 128, 1 << 17, "t"))
        assert any("scratch" in e.message for e in errs)


# ---------------------------------------------------------------------------
# plumbing: registry, filters, report, JSON round-trip
# ---------------------------------------------------------------------------


class TestPlumbing:
    def test_registry_complete(self):
        assert sorted(simlint.RULES) == ["R1", "R2", "R3", "R4", "R5", "R6"]
        for spec in simlint.RULES.values():
            assert spec.entries and spec.doc
            assert set(spec.entries) <= set(simlint.ENTRY_NAMES)

    def test_unknown_rule_and_entry_raise(self):
        with pytest.raises(ValueError, match="R99"):
            simlint.run_lint(rules=["R99"])
        with pytest.raises(ValueError, match="warp_drive"):
            simlint.LintContext(entries=["warp_drive"])

    def test_r6_runs_clean_on_current_tree(self):
        # cheap true-positive check (no engine tracing); the full-tree
        # zero-error sweep is the blocking CI step
        assert _errors(simlint.run_lint(rules=["R6"])) == []

    def test_findings_sorted_and_serializable(self):
        f_err = simlint.Finding("R5", "recompile-hazard", "error", "e", "m")
        f_wrn = simlint.Finding("R2", "donation-aliases", "warning", "e", "m")
        d = f_wrn.to_dict()
        assert d["rule"] == "R2" and d["severity"] == "warning"
        assert simlint.summarize([f_err, f_wrn]) == {
            "error": 1, "warning": 1, "info": 0
        }
        report = simlint.format_report([f_err, f_wrn])
        assert "[FAIL] R5" in report and "[ok  ] R2" in report
