"""Figure-4 scheduling semantics: analytic completion times, all 4 combos."""
import jax
import numpy as np
import pytest

from repro.core import SPACE_SHARED, TIME_SHARED, scenarios, simulate

pytestmark = pytest.mark.tier1

L = 400.0  # seconds per dedicated-core task (4000 MI / 10 MIPS)


@pytest.mark.parametrize(
    "hp,vp,expected",
    [
        # (a) space/space: VM1 t at L,2L; VM2 queued until VM1 drains
        (SPACE_SHARED, SPACE_SHARED, [1, 1, 2, 2, 3, 3, 4, 4]),
        # (b) space/time: VM1 all at 2L; VM2 all at 4L
        (SPACE_SHARED, TIME_SHARED, [2, 2, 2, 2, 4, 4, 4, 4]),
        # (c) time/space: both VMs at half speed, 2 tasks then 2 tasks
        (TIME_SHARED, SPACE_SHARED, [2, 2, 4, 4, 2, 2, 4, 4]),
        # (d) time/time: everything at 4L
        (TIME_SHARED, TIME_SHARED, [4] * 8),
    ],
    ids=["a-space/space", "b-space/time", "c-time/space", "d-time/time"],
)
def test_fig4_completion_times(hp, vp, expected):
    scn = scenarios.fig4_scenario(hp, vp)
    res = jax.jit(simulate)(scn)
    finish = np.array(res.finish_t)
    assert int(res.n_finished) == 8
    np.testing.assert_allclose(finish, np.array(expected) * L, rtol=3e-3)


def test_policy_equivalence_unit_load():
    """1 task per VM, 1 single-core VM per host: all four policies agree."""
    ref = None
    for hp in (SPACE_SHARED, TIME_SHARED):
        for vp in (SPACE_SHARED, TIME_SHARED):
            hosts = scenarios.uniform_hosts(1, 3, cores=1, mips=100.0)
            vms = scenarios.uniform_vms(3, cores=1, mips=100.0)
            cls = scenarios.make_cloudlets(
                np.arange(3), np.full(3, 5000.0), np.zeros(3),
                input_mb=0.0, output_mb=0.0)
            scn = scenarios.Scenario(
                hosts=hosts, vms=vms, cloudlets=cls,
                market=scenarios.uniform_market(1),
                policy=scenarios.make_policy(host_policy=hp, vm_policy=vp))
            res = jax.jit(simulate)(scn)
            f = np.array(res.finish_t)
            np.testing.assert_allclose(f, 50.0, rtol=3e-3)
            if ref is None:
                ref = f
            else:
                np.testing.assert_allclose(f, ref, rtol=1e-5)


def test_space_shared_fcfs_monotone():
    """Under space/space on one single-core host, completion order follows
    submission order (FCFS) for equal-length tasks."""
    hosts = scenarios.uniform_hosts(1, 1, cores=1, mips=100.0, ram_mb=8192.0)
    vms = scenarios.uniform_vms(1, cores=1, mips=100.0)
    n = 6
    cls = scenarios.make_cloudlets(
        np.zeros(n, int), np.full(n, 1000.0), np.arange(n, dtype=float),
        input_mb=0.0, output_mb=0.0)
    scn = scenarios.Scenario(
        hosts=hosts, vms=vms, cloudlets=cls,
        market=scenarios.uniform_market(1),
        policy=scenarios.make_policy())
    res = jax.jit(simulate)(scn)
    finish = np.array(res.finish_t)
    assert (np.diff(finish) > 0).all()
    np.testing.assert_allclose(finish, 10.0 * np.arange(1, n + 1), rtol=3e-3)
