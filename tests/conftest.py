"""Suite-wide collection guards.

The model/serving/training test modules import ``repro.dist`` (sharding
rules + activation-sharding) at module scope.  That subsystem is not built
yet (see ROADMAP.md open items): until it lands, importing those modules is
a hard collection error that aborts ``pytest -x`` before the engine suite
runs.  Skip collecting them — loudly — when ``repro.dist`` is absent, the
same way test_engine.py importorskips ``hypothesis``.
"""
import importlib.util
import warnings

_NEEDS_REPRO_DIST = [
    "test_dryrun_smoke.py",   # subprocess code strings import repro.dist
    "test_hlo_walk.py",
    "test_kernels.py",
    "test_models.py",
    "test_moe_dispatch.py",
    "test_serving.py",
    "test_sharding.py",
    "test_system.py",
    "test_train.py",
]

collect_ignore = []
if importlib.util.find_spec("repro.dist") is None:
    collect_ignore = list(_NEEDS_REPRO_DIST)
    warnings.warn(
        "repro.dist is not built yet: skipping collection of "
        + ", ".join(_NEEDS_REPRO_DIST)
    )
