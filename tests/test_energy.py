"""Energy + topology models (the paper's stated future work, implemented)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scenarios, simulate
from repro.core.energy import PowerModel, Topology

import pytest

pytestmark = pytest.mark.tier1


def _with_models(fed=True, lat=5.0, bw=50.0):
    scn = scenarios.table1_scenario(fed)
    return scn.replace(
        power=PowerModel.uniform(3),
        topology=Topology.uniform(3, latency_s=lat, bw_mbps=bw),
    )


def test_energy_bounded_by_power_envelope():
    scn = _with_models()
    res = jax.jit(simulate)(scn)
    n_hosts = int(np.sum(np.array(scn.hosts.exists)))
    makespan = float(res.end_t)
    total = float(np.sum(np.array(res.energy_j)))
    idle_floor = n_hosts * 93.0 * makespan
    peak_ceil = n_hosts * 135.0 * makespan
    assert idle_floor * 0.99 <= total <= peak_ceil * 1.01


def test_energy_zero_without_power_model():
    res = jax.jit(simulate)(scenarios.table1_scenario(True))
    assert float(np.sum(np.array(res.energy_j))) == 0.0


def test_busy_dc_draws_more_than_idle_dc():
    """DC0 hosts most of the work; per-host average power must exceed the
    idle peers' (utilization term)."""
    scn = _with_models()
    res = jax.jit(simulate)(scn)
    e = np.array(res.energy_j)
    hosts_per_dc = np.sum(np.array(scn.hosts.exists), axis=1)
    per_host = e / np.maximum(hosts_per_dc, 1)
    assert per_host[0] > per_host[1]


def test_topology_migration_delay():
    """Higher inter-DC latency/lower bw delays migrated VMs' completions."""
    fast = jax.jit(simulate)(_with_models(lat=1.0, bw=1000.0))
    slow = jax.jit(simulate)(_with_models(lat=300.0, bw=5.0))
    assert int(fast.n_migrations) == int(slow.n_migrations) == 10
    assert float(slow.mean_turnaround) > float(fast.mean_turnaround) + 50


def test_locality_aware_coordinator():
    """With one distant and one nearby peer, migrations prefer the nearby
    one (latency-penalized ranking)."""
    scn = scenarios.table1_scenario(True)
    lat = jnp.asarray(np.array([
        [0.0, 1.0, 500.0],
        [1.0, 0.0, 500.0],
        [500.0, 500.0, 0.0],
    ], np.float32))
    topo = Topology(latency_s=lat, bw_mbps=jnp.full((3, 3), 100.0, jnp.float32))
    res = jax.jit(simulate)(scn.replace(topology=topo))
    placed = np.array(res.vm_dc)[np.array(res.vm_placed)]
    counts = np.bincount(placed, minlength=3)
    # DC1 (near) absorbs its 5 slots before DC2 (far) is touched
    assert counts[1] >= counts[2]
    assert int(res.n_migrations) == 10


def test_from_coordinates_latency():
    coords = np.array([[0.0, 0.0], [1800.0, 0.0], [0.0, 3600.0]])  # km
    topo = Topology.from_coordinates(coords)
    lat = np.array(topo.latency_s)
    assert lat[0, 0] == 0.0
    assert np.isclose(lat[0, 1], 1.8e6 / (0.6 * 3e8), rtol=1e-5)  # 10 ms
    assert lat[0, 2] > lat[0, 1]
