"""Contention-aware network transfer subsystem (DESIGN.md §13).

Four families:

* **provisioner regression** — the two bugs this PR fixes: NaN-poisoned
  peer selection over disconnected (INF-latency) links terminally failing
  VMs that had feasible peers, and ``energy.migration_delay_matrix``
  omitting ``Policy.migration_fixed_s`` (disagreeing with the delay the
  engine actually charges).
* **fair-share honesty** — k concurrent transfers on one link each finish
  in k× the lone-transfer byte time (exact under the analytic recompute),
  including a hand-computed staggered-join/leave case.
* **flat-path equivalence** — ``topology=None`` scenarios with remote
  input data bill the flat ``interdc_bw_mbps`` divisor; the uniform-
  topology bitwise lock lives in test_invariants.py.
* **driver equivalence** — staging transfers firing leave ``simulate`` /
  ``simulate_trace`` / ``simulate_history`` bit-identical, with K_STAGE
  events visible in the history.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scenarios, simulate, simulate_history, simulate_trace
from repro.core import energy as energy_mod
from repro.core.energy import Topology
from repro.core.step import K_STAGE

pytestmark = pytest.mark.tier1

INF = 3.0e38


def _assert_results_identical(res_a, res_b):
    for f in dataclasses.fields(res_a):
        a, b = getattr(res_a, f.name), getattr(res_b, f.name)
        np.testing.assert_array_equal(
            np.array(a), np.array(b), err_msg=f"SimResult.{f.name} diverged"
        )


def _overflow_scenario(topo, n_overflow=1, image_mb=1024.0, length_mi=500.0,
                       mips=100.0, core_reserving=True):
    """DC0 full, ``n_overflow`` extra VMs must federate out; one cloudlet
    per VM.  DC0 has 1 slot, every peer DC has ``n_overflow`` slots."""
    n_dc = topo.latency_s.shape[0]
    n_vms = 1 + n_overflow
    hosts = scenarios.uniform_hosts(n_dc, n_overflow, cores=1, mips=mips,
                                    ram_mb=4096.0)
    ex = np.ones((n_dc, n_overflow), bool)
    ex[0, 1:] = False                       # DC0: exactly one host
    hosts = hosts.replace(exists=jnp.asarray(ex))
    vms = scenarios.uniform_vms(n_vms, dc=0, cores=1, mips=mips,
                                ram_mb=256.0, image_mb=image_mb)
    cls = scenarios.make_cloudlets(
        np.arange(n_vms), np.full(n_vms, length_mi), np.zeros(n_vms),
        input_mb=0.0, output_mb=0.0)
    pol = scenarios.make_policy(federation=True,
                                core_reserving=core_reserving, horizon=1e6)
    return scenarios.Scenario(
        hosts=hosts, vms=vms, cloudlets=cls,
        market=scenarios.uniform_market(n_dc), policy=pol, topology=topo)


# --------------------------------------------------------------------------
# bug 1: disconnected peers must not poison the peer ranking
# --------------------------------------------------------------------------

def test_disconnected_peer_does_not_poison_selection():
    """3 DCs, DC0 full: DC1 reachable (finite latency), DC2 disconnected
    (INF latency).  The overflow VM must land on DC1.  Pre-fix, the peer
    score normalized by max latency = INF/INF = NaN, argmin landed on the
    NaN row, and the feasible peer was rejected — the VM failed
    terminally."""
    lat = np.full((3, 3), np.inf, np.float32)
    np.fill_diagonal(lat, 0.0)
    lat[0, 1] = lat[1, 0] = 0.05
    topo = Topology(latency_s=jnp.asarray(lat),
                    bw_mbps=jnp.full((3, 3), 100.0, jnp.float32))
    scn = _overflow_scenario(topo)
    r = jax.jit(simulate)(scn)
    assert not bool(np.array(r.vm_failed)[1]), "feasible peer was rejected"
    assert int(np.array(r.vm_dc)[1]) == 1, "must pick the reachable peer"
    assert int(r.n_migrations) == 1
    assert int(r.n_finished) == 2


def test_disconnected_peer_is_last_resort():
    """With the reachable peer full too, the disconnected DC is still
    selectable (flat worst-case penalty, not a NaN): the VM places there
    and pays the INF latency through an unavailable-forever clock rather
    than failing."""
    lat = np.full((2, 2), np.inf, np.float32)
    np.fill_diagonal(lat, 0.0)
    topo = Topology(latency_s=jnp.asarray(lat),
                    bw_mbps=jnp.full((2, 2), 100.0, jnp.float32))
    scn = _overflow_scenario(topo)
    r = jax.jit(simulate)(scn)
    assert not bool(np.array(r.vm_failed)[1])
    assert int(np.array(r.vm_dc)[1]) == 1
    # the image never arrives over a disconnected link
    assert bool(np.array(r.finish_t)[1] >= INF / 2)


# --------------------------------------------------------------------------
# bug 2: migration_delay_matrix agrees with the engine
# --------------------------------------------------------------------------

def test_migration_delay_matrix_includes_fixed_term():
    topo = Topology.uniform(3, latency_s=2.0, bw_mbps=50.0)
    scn = _overflow_scenario(topo)
    image = 1024.0
    m = np.array(energy_mod.migration_delay_matrix(scn, image))
    fixed = float(scn.policy.migration_fixed_s)
    want = fixed + np.array(topo.latency_s) + image / np.array(topo.bw_mbps)
    np.testing.assert_allclose(m, want, rtol=1e-6)
    assert m.min() >= fixed, "fixed VM-creation latency must be included"
    # explicit policy overrides the scenario's
    pol2 = scn.policy.replace(migration_fixed_s=jnp.asarray(7.5, jnp.float32))
    m2 = np.array(energy_mod.migration_delay_matrix(scn, image, policy=pol2))
    np.testing.assert_allclose(m2, want - fixed + 7.5, rtol=1e-6)


def test_migration_delay_matrix_agrees_with_engine():
    """An uncontended federation migration becomes usable exactly when the
    matrix says: finish = matrix[origin, dst] + length/mips."""
    topo = Topology.uniform(2, latency_s=3.0, bw_mbps=40.0)
    scn = _overflow_scenario(topo, length_mi=500.0, mips=100.0)
    r = jax.jit(simulate)(scn)
    assert int(r.n_migrations) == 1
    delay = float(energy_mod.migration_delay_matrix(
        scn, float(scn.vms.image_mb[1]))[0, 1])
    want = delay + 500.0 / 100.0
    np.testing.assert_allclose(float(np.array(r.finish_t)[1]), want,
                               rtol=1e-5)


# --------------------------------------------------------------------------
# fair-share honesty
# --------------------------------------------------------------------------

def _staging_scenario(k, input_mb=1000.0, bw=100.0, lat=0.0,
                      submit=None, length_mi=100.0, mips=100.0):
    """k fixed-binding cloudlets staging ``input_mb`` from DC1 to their own
    VM in DC0 — every transfer shares the single (1, 0) link."""
    hosts = scenarios.uniform_hosts(2, k, cores=1, mips=mips, ram_mb=4096.0)
    vms = scenarios.uniform_vms(k, dc=0, cores=1, mips=mips, ram_mb=256.0)
    sub = np.zeros(k) if submit is None else np.asarray(submit, np.float64)
    cls = scenarios.make_cloudlets(
        np.arange(k), np.full(k, length_mi), sub,
        input_mb=input_mb, output_mb=0.0, input_dc=1)
    pol = scenarios.make_policy(horizon=1e6, interdc_bw_mbps=bw)
    return scenarios.Scenario(
        hosts=hosts, vms=vms, cloudlets=cls,
        market=scenarios.uniform_market(2), policy=pol,
        topology=Topology.uniform(2, latency_s=lat, bw_mbps=bw))


@pytest.mark.parametrize("k", [1, 2, 4])
def test_k_concurrent_stagings_share_the_link(k):
    """k simultaneous stage-ins on one link each take exactly k× the lone
    transfer's byte time (they open in one transfer phase at share bw/k)."""
    bw, mb, lat = 100.0, 1000.0, 0.5
    r = jax.jit(simulate)(_staging_scenario(k, input_mb=mb, bw=bw, lat=lat))
    start = np.array(r.start_t)
    want = lat + k * mb / bw
    np.testing.assert_allclose(start, np.full(k, want), rtol=1e-6)
    # all k are priced in the same recompute: bitwise-equal start times
    assert (start == start[0]).all()
    assert int(r.n_finished) == k


def test_concurrent_migrations_fair_share():
    """k federation migrations committed in one provisioning scan settle to
    the same fair-share completion: fixed + latency + k·image/bw each (the
    same-event recompute re-times the earlier commits to the final k-way
    share)."""
    k, bw, image, lat, mips, length = 3, 50.0, 1024.0, 1.0, 100.0, 500.0
    topo = Topology.uniform(2, latency_s=lat, bw_mbps=bw)
    scn = _overflow_scenario(topo, n_overflow=k, image_mb=image,
                             length_mi=length, mips=mips)
    r = jax.jit(simulate)(scn)
    assert int(r.n_migrations) == k
    fin = np.array(r.finish_t)[1:]           # the k migrated VMs' cloudlets
    fixed = float(scn.policy.migration_fixed_s)
    want = fixed + lat + k * image / bw + length / mips
    np.testing.assert_allclose(fin, np.full(k, want), rtol=1e-5)


def test_staggered_join_hand_computed():
    """Fair-share dynamics, worked by hand (lat=0, bw=100, 1000 MB each,
    T = 10 s alone): A opens at t=0; B joins at s=2 → both at bw/2; A
    finishes at 2T−s = 18; B (800 MB done by then) gets the link back and
    finishes at 2T = 20."""
    r = jax.jit(simulate)(_staging_scenario(
        2, input_mb=1000.0, bw=100.0, lat=0.0, submit=[0.0, 2.0]))
    start = np.array(r.start_t)
    np.testing.assert_allclose(start[0], 18.0, rtol=1e-6)
    np.testing.assert_allclose(start[1], 20.0, rtol=1e-6)
    assert int(r.n_finished) == 2


def test_flat_path_bills_interdc_divisor():
    """``topology=None`` with remote input data: stage-in billed at the
    flat ``interdc_bw_mbps`` divisor, concurrency-blind — k transfers all
    start at input/bw."""
    k, bw, mb = 3, 50.0, 1000.0
    scn = _staging_scenario(k, input_mb=mb, bw=bw)   # VM-local bw is 100
    scn = dataclasses.replace(scn, topology=None)
    r = jax.jit(simulate)(scn)
    np.testing.assert_allclose(
        np.array(r.start_t), np.full(k, mb / bw), rtol=1e-6)
    # local rows (input on the VM's own DC) keep the VM-local divisor
    cls2 = scn.cloudlets.replace(
        input_dc=jnp.zeros_like(scn.cloudlets.input_dc))
    r2 = jax.jit(simulate)(dataclasses.replace(scn, cloudlets=cls2))
    np.testing.assert_allclose(
        np.array(r2.start_t),
        np.full(k, mb / float(scn.vms.bw_mbps[0])), rtol=1e-6)


# --------------------------------------------------------------------------
# driver equivalence with staging traffic
# --------------------------------------------------------------------------

def test_drivers_bitwise_with_staging_firing():
    scn = scenarios.staging_scenario(n_cloudlets=24)
    res = jax.jit(simulate)(scn)
    assert int(res.n_finished) == 24
    ts = jnp.asarray(np.arange(0.0, 300.0, 17.0, dtype=np.float32))
    res_t, prog = simulate_trace(scn, ts)
    _assert_results_identical(res, res_t)
    assert (np.diff(np.array(prog), axis=0) >= -1e-5).all()
    res_h, hist = jax.jit(simulate_history)(scn)
    _assert_results_identical(res, res_h)


def test_stage_event_wakes_loop_for_prebound_rows():
    """A fixed-binding row submitted in the future has no dispatch event to
    open its transfer; the K_STAGE bound must wake the loop at its submit
    time (the staggered-join case above depends on it)."""
    scn = _staging_scenario(2, input_mb=1000.0, bw=100.0, lat=0.0,
                            submit=[0.0, 2.0])
    res, hist = jax.jit(simulate_history)(scn)
    kinds = np.array(hist.kind)[np.array(hist.valid)]
    t = np.array(hist.t)[np.array(hist.valid)]
    assert (kinds == K_STAGE).sum() == 1
    np.testing.assert_allclose(t[kinds == K_STAGE], [2.0])


def test_locality_dispatch_prefers_data_gravity():
    """Under locality dispatch an idle VM co-located with the input beats
    an idle remote VM: the single cloudlet stages over the diagonal
    (intra-DC) link."""
    hosts = scenarios.uniform_hosts(2, 1, cores=1, mips=100.0, ram_mb=4096.0)
    vms = scenarios.uniform_vms(2, dc=np.array([0, 1]), cores=1, mips=100.0,
                                ram_mb=256.0)
    cls = scenarios.make_cloudlets(
        np.array([-1]), np.array([100.0]), np.array([0.0]),
        input_mb=1000.0, output_mb=0.0, input_dc=1)
    topo_lat = Topology.uniform(2, latency_s=0.0, bw_mbps=100.0)
    # slow inter-DC links, fast intra-DC: data gravity should pick VM1
    bw = np.full((2, 2), 10.0, np.float32)
    np.fill_diagonal(bw, 1000.0)
    topo = Topology(latency_s=topo_lat.latency_s, bw_mbps=jnp.asarray(bw))
    for loc, want_vm in ((False, 0), (True, 1)):
        pol = scenarios.make_policy(horizon=1e6, locality_dispatch=loc)
        scn = scenarios.Scenario(
            hosts=hosts, vms=vms, cloudlets=cls,
            market=scenarios.uniform_market(2), policy=pol, topology=topo)
        r = jax.jit(simulate)(scn)
        assert int(np.array(r.cl_vm)[0]) == want_vm, f"locality={loc}"
        assert int(r.n_finished) == 1
