"""repro.dist contract tests: no-op guarantee, 1-device CI meshes, named().

tests/test_sharding.py covers spec validity on the 16x16 production
AbstractMesh; this module covers the other half of the contract — the
subsystem must also be exactly inert outside its context and valid on the
trivial meshes CPU CI actually runs on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from test_sharding import _check_specs

from repro.configs import ARCH_IDS, get_config
from repro.dist import (
    activation_shardings,
    current_state,
    input_pspec_tree,
    named,
    param_pspec_tree,
    rules_for_mesh,
    shard_act,
)
from repro.launch.mesh import make_host_mesh
from repro.models import DECODE_32K, TRAIN_4K, build_model

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------ no-op
def test_shard_act_identity_eager():
    x = jnp.arange(24.0).reshape(2, 3, 4)
    assert current_state() is None
    assert shard_act(x, ("batch", None, "model")) is x  # not even a copy


def test_shard_act_identity_under_jit():
    x = jnp.arange(24.0).reshape(2, 3, 4)
    ident = jax.jit(lambda a: shard_act(a, ("batch", "seq", "model")))
    np.testing.assert_array_equal(np.asarray(ident(x)), np.asarray(x))
    # no constraint op in the jaxpr: bit-identical program, not just values
    jaxpr = jax.make_jaxpr(lambda a: shard_act(a, ("batch", None, None)))(x)
    assert not jaxpr.jaxpr.eqns, jaxpr


def test_context_sets_and_restores_state():
    mesh = make_host_mesh((1, 1))
    assert current_state() is None
    with activation_shardings(mesh, sequence_parallel=True) as st:
        mesh_, rules, seq_par = current_state()
        assert st == (mesh_, rules, seq_par)
        assert mesh_ is mesh and seq_par is True
        assert rules.tp == "model" and rules.batch == ("data",)
    assert current_state() is None


def test_shard_act_constrains_under_context():
    """Inside the context on a 1-device mesh: same values, constraint applied."""
    mesh = make_host_mesh((1, 1))
    x = jnp.arange(24.0).reshape(2, 3, 4)
    with activation_shardings(mesh):
        f = jax.jit(lambda a: shard_act(a, ("batch", None, "model")) * 1.0)
        out = f(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_shard_act_rejects_unknown_logical_axis():
    mesh = make_host_mesh((1, 1))
    with activation_shardings(mesh):
        with pytest.raises(ValueError, match="logical"):
            shard_act(jnp.zeros((4, 4)), ("batch", "modle"))


# --------------------------------------------------- 1-device CI meshes
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_specs_valid_on_trivial_mesh(arch):
    """The same rule tables must produce valid specs on the 1-device mesh
    CPU CI runs on (every divisibility fallback degenerates gracefully)."""
    mesh = make_host_mesh((1, 1))
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, KEY)
    _check_specs(shapes, param_pspec_tree(shapes, mesh), mesh)
    for cell in (TRAIN_4K, DECODE_32K):
        specs = model.input_specs(cell)
        _check_specs(specs, input_pspec_tree(specs, mesh), mesh)


def test_fsdp_strategy_has_no_tp():
    mesh = make_host_mesh((1, 1))
    rules = rules_for_mesh(mesh, "fsdp")
    assert rules.tp is None
    assert set(rules.batch) == {"data", "model"}
    with pytest.raises(ValueError, match="strategy"):
        rules_for_mesh(mesh, "3d")


# ------------------------------------------------------------- named()
def test_rules_round_trip_through_named():
    """rules -> pspec tree -> NamedSharding tree: structure and specs survive."""
    mesh = make_host_mesh((1, 1))
    cfg = get_config("internlm2-1.8b", smoke=True)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, KEY)
    specs = param_pspec_tree(shapes, mesh)
    shardings = named(mesh, specs)
    assert jax.tree.structure(shapes) == jax.tree.structure(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding)
    )
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    shard_leaves = jax.tree.leaves(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding)
    )
    for spec, sh in zip(spec_leaves, shard_leaves):
        assert isinstance(sh, NamedSharding)
        assert sh.mesh is mesh
        assert sh.spec == spec
    # and the shardings are usable: device_put a leaf through the tree
    p = jax.device_put(jnp.zeros((cfg.vocab, cfg.d_model)), shard_leaves[0])
    assert p.sharding.is_equivalent_to(shard_leaves[0], p.ndim)
