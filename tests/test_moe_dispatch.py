"""MoE dispatch invariants (property-based)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.moe import _capacity, _dispatch_slots


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 5000), n=st.integers(1, 200),
       e=st.sampled_from([2, 4, 8]), cap=st.integers(1, 32))
def test_dispatch_slots_invariants(seed, n, e, cap):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, e + 1, n).astype(np.int32))  # e = drop
    order, e_sorted, slot, keep = _dispatch_slots(ids, e, cap)
    order, e_sorted = np.array(order), np.array(e_sorted)
    slot, keep = np.array(slot), np.array(keep)
    # sorted grouping
    assert (np.diff(e_sorted) >= 0).all()
    # kept slots unique per expert and < cap
    for ex in range(e):
        s = slot[(e_sorted == ex) & keep]
        assert len(np.unique(s)) == len(s)
        assert (s < cap).all() and (s >= 0).all()
        # FCFS: kept entries are the FIRST cap entries of that expert
        all_s = slot[e_sorted == ex]
        assert (np.sort(s) == np.arange(len(s))).all()
        assert len(s) == min(len(all_s), cap)
    # overflow ids (== e) never kept
    assert not keep[e_sorted >= e].any()


def test_capacity_rounding():
    assert _capacity(100, 4, 2, 1.25) % 8 == 0
    assert _capacity(1, 128, 8, 1.0) >= 8
    assert _capacity(16384, 128, 8, 1.25) >= 16384 * 8 * 1.25 / 128
