"""MoE dispatch invariants (deterministic property sweep).

Property-style coverage without the optional hypothesis dependency (absent
in the container image): each seed derives a random (n, e, cap) case, so 40
parametrized seeds sweep the same space ``@given`` did.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import _capacity, _dispatch_slots


@pytest.mark.parametrize("seed", range(40))
def test_dispatch_slots_invariants(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 201))
    e = int(rng.choice([2, 4, 8]))
    cap = int(rng.integers(1, 33))
    ids = jnp.asarray(rng.integers(0, e + 1, n).astype(np.int32))  # e = drop
    order, e_sorted, slot, keep = _dispatch_slots(ids, e, cap)
    order, e_sorted = np.array(order), np.array(e_sorted)
    slot, keep = np.array(slot), np.array(keep)
    # sorted grouping
    assert (np.diff(e_sorted) >= 0).all()
    # kept slots unique per expert and < cap
    for ex in range(e):
        s = slot[(e_sorted == ex) & keep]
        assert len(np.unique(s)) == len(s)
        assert (s < cap).all() and (s >= 0).all()
        # FCFS: kept entries are the FIRST cap entries of that expert
        all_s = slot[e_sorted == ex]
        assert (np.sort(s) == np.arange(len(s))).all()
        assert len(s) == min(len(all_s), cap)
    # overflow ids (== e) never kept
    assert not keep[e_sorted >= e].any()


def test_capacity_rounding():
    assert _capacity(100, 4, 2, 1.25) % 8 == 0
    assert _capacity(1, 128, 8, 1.0) >= 8
    assert _capacity(16384, 128, 8, 1.25) >= 16384 * 8 * 1.25 / 128
