"""KV-cache-bound continuous batching (DESIGN.md §14).

Covers the serving tentpole end to end:

* hand-computed admission interleave and batch-degradation timing,
* preemption-on-exhaustion (youngest-first) with token-boundary rollback,
* KV-block conservation — VM pools and the host ledger — through
  preempt/re-admit churn, probed at every event by an instrument,
* driver equivalence (simulate == simulate_trace == batch-major, bitwise)
  with serving cloudlets firing,
* serving-off inertness: scenarios without serving rows report the INF
  sentinels and zero serving state while legacy fields match the analytic
  fig4 values exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import campaign, engine, kvserve, reducers, segments
from repro.core import step as step_mod
from repro.core.entities import INF, SPACE_SHARED, Scenario
from repro.core.pytree import pytree_dataclass
from repro.core.scenarios import (
    fig4_scenario,
    make_cloudlets,
    make_policy,
    serving_scenario,
    uniform_hosts,
    uniform_market,
    uniform_vms,
)

pytestmark = pytest.mark.tier1


def _serving_fixture(*, kv_blocks, block_tokens=4.0, batch_degradation=0.0,
                     prompts=(4.0, 4.0), max_new=(8.0, 8.0), mips=100.0,
                     token_mi=10.0, submit=None):
    """One host / one VM / fixed-binding serving rows — small enough to
    hand-compute every admission, boundary and completion."""
    n = len(prompts)
    hosts = uniform_hosts(1, 1, cores=1, mips=mips, ram_mb=4096.0,
                          kv_blocks=kv_blocks)
    vms = uniform_vms(1, cores=1, mips=mips, ram_mb=1024.0,
                      kv_blocks=kv_blocks)
    max_new = np.asarray(max_new, np.float32)
    cls = make_cloudlets(
        np.zeros(n, np.int32), max_new * token_mi,
        np.zeros(n) if submit is None else np.asarray(submit),
        input_mb=0.0, output_mb=0.0,
        prompt_tokens=np.asarray(prompts, np.float32),
        max_new_tokens=max_new,
    )
    pol = make_policy(host_policy=SPACE_SHARED, vm_policy=SPACE_SHARED,
                      block_tokens=block_tokens,
                      batch_degradation=batch_degradation)
    return Scenario(hosts=hosts, vms=vms, cloudlets=cls,
                    market=uniform_market(1), policy=pol, max_steps=400)


@pytree_dataclass
class KVProbe(step_mod.Instrument):
    """Max pool overshoot / host-ledger violation / final rollback observed
    across every event — the conservation invariants, probed in-loop."""

    name = "kvprobe"

    def init(self, scn):
        z = jnp.asarray(0.0, jnp.float32)
        return (z, z, z, jnp.asarray(0, jnp.int32))

    def post(self, scn, st, ev, aux):
        pool_over, host_over, _rollback, evictions = aux
        V = scn.vms.n_vms
        vmi = jnp.clip(st.cl_vm, 0, V - 1)
        seg = jnp.where(st.cl_admitted, vmi, V)
        usage = segments.segment_sum(
            jnp.where(st.cl_admitted, st.cl_kv, 0.0), seg, V)
        pool_over = jnp.maximum(
            pool_over, jnp.max(usage - scn.vms.kv_blocks))
        ledger_bad = jnp.maximum(
            -st.free_kv, st.free_kv - scn.hosts.kv_blocks)
        host_over = jnp.maximum(host_over, jnp.max(ledger_bad))
        return st, (pool_over, host_over,
                    jnp.sum(st.cl_rollback_mi), evictions)

    def finalize(self, scn, st, aux):
        return {"pool_over": aux[0], "host_over": aux[1],
                "rollback": aux[2]}


def _assert_results_identical(res_a, res_b):
    for f in dataclasses.fields(res_a):
        np.testing.assert_array_equal(
            np.asarray(getattr(res_a, f.name)),
            np.asarray(getattr(res_b, f.name)),
            err_msg=f"SimResult.{f.name} differs",
        )


# ---------------------------------------------------------------------------
# continuous-batching honesty: hand-computed timings
# ---------------------------------------------------------------------------

class TestBatchingSemantics:
    def test_batch_degradation_two_requests(self):
        # both admitted at t=0, batch of 2, alpha=0.5 -> each decodes at
        # 100 / 1.5 MIPS; 8 tokens x 10 MI finish at 80 / (100/1.5) = 1.2 s
        scn = _serving_fixture(kv_blocks=32.0, batch_degradation=0.5)
        res = jax.jit(engine.simulate)(scn)
        assert int(res.n_finished) == 2
        np.testing.assert_allclose(
            np.asarray(res.finish_t), [1.2, 1.2], rtol=1e-4)
        np.testing.assert_allclose(np.asarray(res.start_t), [0.0, 0.0])
        np.testing.assert_allclose(float(res.tpot_p50), 0.15, rtol=1e-4)

    def test_solo_decode_is_undegraded(self):
        scn = _serving_fixture(kv_blocks=32.0, batch_degradation=0.5,
                               prompts=(4.0,), max_new=(8.0,))
        res = jax.jit(engine.simulate)(scn)
        np.testing.assert_allclose(np.asarray(res.finish_t), [0.8], rtol=1e-4)

    def test_admission_interleave_hand_computed(self):
        # pool of 3 blocks, 2 blocks per fresh request (prompt 4 + open
        # block @ 4 tokens/block): r0 admits alone, r1 waits.  r0 decodes
        # 8 tokens at 100 MIPS (0.8 s), releases, r1 admits at the
        # completion event and finishes 0.8 s later.  TTFT(r1) = 0.8.
        scn = _serving_fixture(kv_blocks=3.0)
        res = jax.jit(engine.simulate)(scn)
        assert int(res.n_finished) == 2
        np.testing.assert_allclose(
            np.asarray(res.start_t), [0.0, 0.8], rtol=1e-4)
        np.testing.assert_allclose(
            np.asarray(res.finish_t), [0.8, 1.6], rtol=1e-4)
        np.testing.assert_allclose(float(res.ttft_p99), 0.8, rtol=1e-4)

    def test_preemption_on_exhaustion_youngest_first(self):
        # pool of 5, both requests admit with 2 blocks; at the first block
        # boundary (4 tokens, t=0.4) both need 3 -> 6 > 5, so the YOUNGER
        # row r1 is evicted at its token boundary (zero re-done work), r0
        # finishes alone at 1.2, r1 re-admits and finishes at 2.0.
        scn = _serving_fixture(kv_blocks=5.0, max_new=(12.0, 12.0))
        probe = KVProbe()
        res, out = jax.jit(
            lambda s: engine.simulate_instrumented(s, (probe,)),
        )(scn)
        assert int(res.n_finished) == 2
        np.testing.assert_allclose(
            np.asarray(res.finish_t), [1.2, 2.0], rtol=1e-4)
        # eviction landed exactly on a token boundary: no re-done work
        np.testing.assert_allclose(float(out["kvprobe"]["rollback"]), 0.0,
                                   atol=1e-3)
        # conservation held through the preempt/re-admit churn
        assert float(out["kvprobe"]["pool_over"]) <= 1e-4
        assert float(out["kvprobe"]["host_over"]) <= 1e-4


# ---------------------------------------------------------------------------
# KV-block conservation under sustained pressure
# ---------------------------------------------------------------------------

class TestKVConservation:
    def test_pressured_fleet_never_oversubscribes(self):
        scn = serving_scenario(
            jax.random.PRNGKey(3), n_requests=32, n_replicas=2,
            kv_blocks=24.0, rate=2.0, batch_degradation=0.1,
            median_prompt=64.0, median_new=48.0)
        probe = KVProbe()
        res, out = jax.jit(
            lambda s: engine.simulate_instrumented(s, (probe,)),
        )(scn)
        assert int(res.n_finished) > 0
        assert float(out["kvprobe"]["pool_over"]) <= 1e-4
        assert float(out["kvprobe"]["host_over"]) <= 1e-4

    def test_blocks_needed_matches_paged_attention_count(self):
        scn = _serving_fixture(kv_blocks=32.0, prompts=(4.0, 9.0),
                               max_new=(8.0, 8.0))
        st = engine.init_state(scn)
        need = np.asarray(kvserve.blocks_needed(scn, st))
        # prompt 4 @ 4/block -> 1 full block + open block = 2;
        # prompt 9 -> ceil(9/4)=3 filled (one partial) + ... floor(9.1/4)+1=3
        np.testing.assert_allclose(need, [2.0, 3.0])


# ---------------------------------------------------------------------------
# driver equivalence with serving cloudlets firing
# ---------------------------------------------------------------------------

class TestDriverEquivalence:
    def _scn(self):
        return serving_scenario(
            jax.random.PRNGKey(11), n_requests=24, n_replicas=2, n_pool=1,
            kv_blocks=24.0, rate=1.5, autoscale=True,
            batch_degradation=0.1, median_prompt=64.0, median_new=48.0)

    def test_simulate_equals_trace_and_history(self):
        scn = self._scn()
        res = jax.jit(engine.simulate)(scn)
        assert int(res.n_finished) > 0
        assert float(res.ttft_p50) < INF / 2   # serving metrics populated
        res_tr, _ = jax.jit(engine.simulate_trace)(
            scn, jnp.asarray([5.0, 20.0], jnp.float32))
        _assert_results_identical(res, res_tr)
        res_h, hist = engine.simulate_history(scn)
        _assert_results_identical(res, res_h)
        # K_SERVING boundary stops actually fired in the event stream
        kinds = np.asarray(hist.kind)[np.asarray(hist.valid)]
        assert (kinds == step_mod.K_SERVING).sum() > 0

    def test_batch_major_rows_bitwise_match_solo(self):
        rows = [
            serving_scenario(
                jax.random.PRNGKey(11), n_requests=24, n_replicas=2,
                n_pool=1, kv_blocks=kv, rate=1.5, autoscale=True,
                scale_up_thresh=th, batch_degradation=0.1,
                median_prompt=64.0, median_new=48.0, max_steps=1500)
            for kv in (16.0, 32.0) for th in (0.6, 0.9)
        ]
        batched = campaign.stack_scenarios(rows)
        res_b = jax.jit(engine.simulate)(batched)
        for i, row in enumerate(rows):
            solo = jax.jit(engine.simulate)(row)
            _assert_results_identical(
                jax.tree.map(lambda x: x[i], res_b), solo)

    def test_latency_reducer_pools_requests(self):
        rows = [self._scn() for _ in range(3)]
        batched = campaign.stack_scenarios(rows)
        out = campaign.run_campaign(batched, chunk_size=2, reduce={
            "ttft": reducers.LatencyHistogramReducer(
                "ttft", lo=0.0, hi=10.0, bins=64, qs=(0.5, 0.99)),
        })
        n_served = sum(
            int(jax.jit(engine.simulate)(r).n_finished) for r in rows)
        assert int(np.asarray(out["ttft"]["counts"]).sum()) == n_served


# ---------------------------------------------------------------------------
# serving-off inertness
# ---------------------------------------------------------------------------

class TestServingOffInert:
    def test_fig4_reports_sentinels_and_analytic_times(self):
        scn = fig4_scenario(SPACE_SHARED, SPACE_SHARED)
        res = jax.jit(engine.simulate)(scn)
        # legacy semantics untouched: the paper's Figure-4a analytic times
        L = 400.0
        np.testing.assert_allclose(
            np.sort(np.asarray(res.finish_t)),
            np.sort(np.asarray([L, L, 2 * L, 2 * L,
                                3 * L, 3 * L, 4 * L, 4 * L])))
        for f in ("ttft_p50", "ttft_p99", "tpot_p50", "tpot_p99"):
            assert float(getattr(res, f)) >= INF / 2

    def test_no_serving_state_ever_set(self):
        scn = fig4_scenario(SPACE_SHARED, SPACE_SHARED)
        probe = KVProbe()
        _, out = jax.jit(
            lambda s: engine.simulate_instrumented(s, (probe,)),
        )(scn)
        assert float(out["kvprobe"]["rollback"]) == 0.0
        assert float(out["kvprobe"]["pool_over"]) <= 0.0
        assert float(out["kvprobe"]["host_over"]) <= 0.0
