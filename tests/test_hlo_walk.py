"""HLO walker: trip-count weighting, dot flops, collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_walk import analyze_hlo
from repro.analysis.roofline import analyze_walk


def _hlo(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


def test_scan_trip_count_weighting():
    def f(x, ws):
        def body(c, w):
            return (c @ w) @ w.T, None
        c, _ = jax.lax.scan(body, x, ws)
        return c

    txt = _hlo(f, jax.ShapeDtypeStruct((128, 256), jnp.float32),
               jax.ShapeDtypeStruct((10, 256, 256), jnp.float32))
    t = analyze_hlo(txt)
    expect = 10 * 2 * (2 * 128 * 256 * 256)
    np.testing.assert_allclose(t.dot_flops, expect, rtol=1e-6)


def test_nested_scan():
    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=5)
            return ci, None
        c, _ = jax.lax.scan(outer, x, ws)
        return c

    txt = _hlo(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
               jax.ShapeDtypeStruct((3, 64, 64), jnp.float32))
    t = analyze_hlo(txt)
    expect = 3 * 5 * 2 * 64 * 64 * 64
    np.testing.assert_allclose(t.dot_flops, expect, rtol=1e-6)


def test_unrolled_matmul():
    def f(a, b):
        return a @ b

    txt = _hlo(f, jax.ShapeDtypeStruct((32, 64), jnp.float32),
               jax.ShapeDtypeStruct((64, 128), jnp.float32))
    t = analyze_hlo(txt)
    np.testing.assert_allclose(t.dot_flops, 2 * 32 * 64 * 128, rtol=1e-6)


def test_roofline_bottleneck_logic():
    class W:  # minimal stand-in
        dot_flops = 197e12  # exactly 1s of compute
        coll_counts = {"all-reduce": 1}
        coll_raw = {"all-reduce": 1e9}
        coll_effective = 5e9  # 0.1 s

    class M:
        traffic_bytes = 819e9 * 2  # 2 s of HBM -> memory-bound

    r = analyze_walk(W(), M(), n_chips=4, model_flops=100e12)
    assert r.bottleneck == "memory"
    assert np.isclose(r.compute_s, 1.0)
    assert np.isclose(r.memory_s, 2.0)
    assert np.isclose(r.collective_s, 0.1)
    assert np.isclose(r.step_time_s, 2.0)


_COND_HLO = """
HloModule cond_walk_test

%branch_heavy (p.1: f32[8,8]) -> f32[8,8] {
  %p.1 = f32[8,8]{1,0} parameter(0)
  ROOT %dot.1 = f32[8,8]{1,0} dot(f32[8,8]{1,0} %p.1, f32[8,8]{1,0} %p.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%branch_id (p.2: f32[8,8]) -> f32[8,8] {
  %p.2 = f32[8,8]{1,0} parameter(0)
  ROOT %copy.1 = f32[8,8]{1,0} copy(f32[8,8]{1,0} %p.2)
}

%body.1 (c.1: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %c.1 = (s32[], f32[8,8]) parameter(0)
  %i.1 = s32[] get-tuple-element((s32[], f32[8,8]) %c.1), index=0
  %x.1 = f32[8,8]{1,0} get-tuple-element((s32[], f32[8,8]) %c.1), index=1
  %conditional.1 = f32[8,8]{1,0} conditional(s32[] %i.1, f32[8,8]{1,0} %x.1, f32[8,8]{1,0} %x.1), branch_computations={%branch_heavy, %branch_id}
  ROOT %tuple.1 = (s32[], f32[8,8]) tuple(s32[] %i.1, f32[8,8]{1,0} %conditional.1)
}

%cond.1 (c.2: (s32[], f32[8,8])) -> pred[] {
  %c.2 = (s32[], f32[8,8]) parameter(0)
  %i.2 = s32[] get-tuple-element((s32[], f32[8,8]) %c.2), index=0
  %k.1 = s32[] constant(7)
  ROOT %lt.1 = pred[] compare(s32[] %i.2, s32[] %k.1), direction=LT
}

ENTRY %main.1 (x.0: f32[8,8]) -> f32[8,8] {
  %x.0 = f32[8,8]{1,0} parameter(0)
  %z.1 = s32[] constant(0)
  %t.1 = (s32[], f32[8,8]) tuple(s32[] %z.1, f32[8,8]{1,0} %x.0)
  %while.1 = (s32[], f32[8,8]) while((s32[], f32[8,8]) %t.1), condition=%cond.1, body=%body.1
  ROOT %r.1 = f32[8,8]{1,0} get-tuple-element((s32[], f32[8,8]) %while.1), index=1
}
"""

_PRED_COND_HLO = """
HloModule pred_cond_walk_test

%true_comp (p.1: f32[4,4]) -> f32[4,4] {
  %p.1 = f32[4,4]{1,0} parameter(0)
  ROOT %dot.1 = f32[4,4]{1,0} dot(f32[4,4]{1,0} %p.1, f32[4,4]{1,0} %p.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%false_comp (p.2: f32[4,4]) -> f32[4,4] {
  %p.2 = f32[4,4]{1,0} parameter(0)
  ROOT %copy.1 = f32[4,4]{1,0} copy(f32[4,4]{1,0} %p.2)
}

ENTRY %main.1 (pr.0: pred[], x.0: f32[4,4]) -> f32[4,4] {
  %pr.0 = pred[] parameter(0)
  %x.0 = f32[4,4]{1,0} parameter(1)
  ROOT %conditional.1 = f32[4,4]{1,0} conditional(pred[] %pr.0, f32[4,4]{1,0} %x.0, f32[4,4]{1,0} %x.0), true_computation=%true_comp, false_computation=%false_comp
}
"""


def test_conditional_branches_walked_and_trip_weighted():
    """A dot inside a conditional branch inside a while must be counted,
    weighted by the loop's trip count (the R1 parsing substrate)."""
    t = analyze_hlo(_COND_HLO)
    # 7 trips (max s32 constant in the condition) x one 8x8x8 dot per visit;
    # both branches are walked (conservative upper bound), the empty branch
    # contributes nothing.
    np.testing.assert_allclose(t.dot_flops, 7 * 2 * 8 * 8 * 8, rtol=1e-6)


def test_pred_conditional_true_false_computations_walked():
    t = analyze_hlo(_PRED_COND_HLO)
    np.testing.assert_allclose(t.dot_flops, 2 * 4 * 4 * 4, rtol=1e-6)
