"""HLO walker: trip-count weighting, dot flops, collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_walk import analyze_hlo
from repro.analysis.roofline import Roofline, analyze_walk
from repro.analysis import memory as memest


def _hlo(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


def test_scan_trip_count_weighting():
    def f(x, ws):
        def body(c, w):
            return (c @ w) @ w.T, None
        c, _ = jax.lax.scan(body, x, ws)
        return c

    txt = _hlo(f, jax.ShapeDtypeStruct((128, 256), jnp.float32),
               jax.ShapeDtypeStruct((10, 256, 256), jnp.float32))
    t = analyze_hlo(txt)
    expect = 10 * 2 * (2 * 128 * 256 * 256)
    np.testing.assert_allclose(t.dot_flops, expect, rtol=1e-6)


def test_nested_scan():
    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=5)
            return ci, None
        c, _ = jax.lax.scan(outer, x, ws)
        return c

    txt = _hlo(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
               jax.ShapeDtypeStruct((3, 64, 64), jnp.float32))
    t = analyze_hlo(txt)
    expect = 3 * 5 * 2 * 64 * 64 * 64
    np.testing.assert_allclose(t.dot_flops, expect, rtol=1e-6)


def test_unrolled_matmul():
    def f(a, b):
        return a @ b

    txt = _hlo(f, jax.ShapeDtypeStruct((32, 64), jnp.float32),
               jax.ShapeDtypeStruct((64, 128), jnp.float32))
    t = analyze_hlo(txt)
    np.testing.assert_allclose(t.dot_flops, 2 * 32 * 64 * 128, rtol=1e-6)


def test_roofline_bottleneck_logic():
    class W:  # minimal stand-in
        dot_flops = 197e12  # exactly 1s of compute
        coll_counts = {"all-reduce": 1}
        coll_raw = {"all-reduce": 1e9}
        coll_effective = 5e9  # 0.1 s

    class M:
        traffic_bytes = 819e9 * 2  # 2 s of HBM -> memory-bound

    r = analyze_walk(W(), M(), n_chips=4, model_flops=100e12)
    assert r.bottleneck == "memory"
    assert np.isclose(r.compute_s, 1.0)
    assert np.isclose(r.memory_s, 2.0)
    assert np.isclose(r.collective_s, 0.1)
    assert np.isclose(r.step_time_s, 2.0)
