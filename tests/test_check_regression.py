"""The bench-regression gate must report EVERY failing gated key, not stop
at the first: a single missing benchmark section used to abort the whole
check, masking real regressions in the other five sections."""
import json

import pytest

from benchmarks import check_regression as cr

pytestmark = pytest.mark.tier1


def _report(scale=1.0, drop=(), **overrides):
    """A synthetic report covering every gated key at ``100 * scale``."""
    out: dict = {}
    for section, impl, metric in cr.GATED:
        if (section, impl, metric) in drop or section in drop:
            continue
        out.setdefault(section, {}).setdefault(impl, {})[metric] = (
            overrides.get(section, 100.0 * scale)
        )
    return out


class TestCheck:
    def test_clean_pair_passes(self):
        regs, bad = cr.check(_report(), _report(scale=0.9), tol=0.5)
        assert regs == [] and bad == []

    def test_all_regressions_reported(self):
        # three sections regress below tol: all three lines must come back
        fresh = _report(
            engine_fig9_10=10.0, migration_sweep=20.0, reliability_sweep=5.0
        )
        regs, bad = cr.check(_report(), fresh, tol=0.5)
        assert bad == []
        assert len(regs) == 3
        joined = "\n".join(regs)
        for sect in ("engine_fig9_10", "migration_sweep", "reliability_sweep"):
            assert sect in joined

    def test_missing_key_does_not_mask_other_failures(self):
        # one section missing AND another regressed: both must surface
        fresh = _report(drop=("event_engine_single",), migration_sweep=1.0)
        regs, bad = cr.check(_report(), fresh, tol=0.5)
        assert len(bad) == 1 and "event_engine_single" in bad[0]
        assert len(regs) == 1 and "migration_sweep" in regs[0]

    def test_multiple_missing_keys_all_reported(self):
        fresh = _report(drop=("engine_fig9_10", "reliability_sweep"))
        regs, bad = cr.check(_report(), fresh, tol=0.5)
        assert regs == []
        assert len(bad) == 2

    def test_non_positive_value_is_malformed(self):
        regs, bad = cr.check(_report(), _report(engine_fig9_10=0.0), tol=0.5)
        assert any("non-positive" in b for b in bad)


class TestMainExitCodes:
    def _write(self, tmp_path, name, data):
        p = tmp_path / name
        p.write_text(json.dumps(data))
        return str(p)

    def _run(self, tmp_path, baseline, fresh, tol="0.5"):
        return cr.main([
            "--baseline", self._write(tmp_path, "base.json", baseline),
            "--fresh", self._write(tmp_path, "fresh.json", fresh),
            "--tol", tol,
        ])

    def test_ok_exit_0(self, tmp_path):
        assert self._run(tmp_path, _report(), _report()) == 0

    def test_regression_exit_1(self, tmp_path):
        assert self._run(tmp_path, _report(), _report(scale=0.1)) == 1

    def test_missing_key_exit_2_even_with_regressions(self, tmp_path, capsys):
        fresh = _report(drop=("advance_sweep_kernel",), migration_sweep=1.0)
        assert self._run(tmp_path, _report(), fresh) == 2
        err = capsys.readouterr().err
        # the masking bug: the regression must still be printed
        assert "migration_sweep" in err and "advance_sweep_kernel" in err

    def test_unreadable_report_exit_2(self, tmp_path):
        assert cr.main([
            "--baseline", str(tmp_path / "nope.json"),
            "--fresh", self._write(tmp_path, "fresh.json", _report()),
        ]) == 2
