"""Federation (Table 1) + provisioner behaviour."""
import jax
import numpy as np

from repro.core import scenarios, simulate

import pytest

pytestmark = pytest.mark.tier1


def test_table1_federation_claims():
    """Paper §5: federation cuts mean turnaround >50% (we land ~55%) and
    improves makespan ~20% (we land 25%)."""
    res = {}
    for fed in (False, True):
        r = jax.jit(simulate)(scenarios.table1_scenario(fed))
        assert int(r.n_finished) == 25
        res[fed] = r
    tat_cut = 1 - float(res[True].mean_turnaround) / float(
        res[False].mean_turnaround)
    mk_cut = 1 - float(res[True].makespan) / float(res[False].makespan)
    assert tat_cut > 0.50, f"TAT reduction {tat_cut:.2%} (paper: >50%)"
    assert 0.10 < mk_cut < 0.40, f"makespan improvement {mk_cut:.2%} (~20%)"
    assert int(res[True].n_migrations) == 10
    assert int(res[False].n_migrations) == 0


def test_migration_only_on_slot_exhaustion():
    """VMs stay home while the origin has free slots (paper's rule)."""
    scn = scenarios.table1_scenario(True, n_vms=7)  # 7 fits DC0's 7 hosts
    r = jax.jit(simulate)(scn)
    assert int(r.n_migrations) == 0
    placed_dc = np.array(r.vm_dc)[np.array(r.vm_placed)]
    # background VMs on 1/2; all user VMs on 0
    assert (np.bincount(placed_dc, minlength=3)[0]) == 7


def test_migration_delay_applied():
    """Migrated VMs become usable only after fixed + image/bw delay."""
    scn = scenarios.table1_scenario(True)
    r = jax.jit(simulate)(scn)
    fin = np.array(r.finish_t)
    # fastest possible for migrated work: 30s fixed + 1024/100 MB/s + 1800s
    migrated_floor = 30.0 + 1024 / 100.0 + 1800.0
    done = np.isfinite(fin) & (fin < 1e30)
    # the 10 fastest-finishing slot VMs at DC0 finish before any migrant
    fin_sorted = np.sort(fin[done])
    assert fin_sorted[0] >= 1800.0  # nobody beats physics
    assert (fin_sorted >= 1800.0).all()
    # someone finishes in the migrated band
    assert ((fin_sorted >= migrated_floor) & (fin_sorted < 2000)).any()


def test_best_fit_vs_first_fit():
    """Best-fit packs the tightest host; first-fit the first host."""
    import jax.numpy as jnp

    hosts = scenarios.uniform_hosts(1, 3, cores=4, mips=100.0,
                                    ram_mb=1024.0)
    hosts = hosts.replace(
        ram_mb=jnp.asarray(np.array([[1024.0, 300.0, 600.0]], np.float32)))
    vms = scenarios.uniform_vms(1, ram_mb=256.0)
    cls = scenarios.make_cloudlets(np.array([0]), np.array([100.0]),
                                   np.array([0.0]), input_mb=0.0,
                                   output_mb=0.0)
    for best_fit, want_host in ((False, 0), (True, 1)):
        scn = scenarios.Scenario(
            hosts=hosts, vms=vms, cloudlets=cls,
            market=scenarios.uniform_market(1),
            policy=scenarios.make_policy(best_fit=best_fit))
        from repro.core import engine, provision

        st = engine.init_state(scn)
        st, n = provision.provision_due_vms(scn, st)
        assert int(n) == 1
        assert int(st.vm_host[0]) == want_host, (best_fit, st.vm_host)


def test_failed_placement_is_terminal():
    """A VM that fits nowhere fails and its cloudlets never run."""
    hosts = scenarios.uniform_hosts(1, 2, cores=1, mips=100.0, ram_mb=128.0)
    vms = scenarios.uniform_vms(1, ram_mb=512.0)  # too big
    cls = scenarios.make_cloudlets(np.array([0]), np.array([100.0]),
                                   np.array([0.0]), input_mb=0.0,
                                   output_mb=0.0)
    scn = scenarios.Scenario(
        hosts=hosts, vms=vms, cloudlets=cls,
        market=scenarios.uniform_market(1),
        policy=scenarios.make_policy(horizon=1e4))
    r = jax.jit(simulate)(scn)
    assert bool(np.array(r.vm_failed)[0])
    assert int(r.n_finished) == 0
