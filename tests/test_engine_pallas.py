"""Engine end-to-end with the Pallas advance sweep (interpret mode) — the
kernel in its production seat, not just standalone."""
import jax
import numpy as np
import pytest

from repro.core import SPACE_SHARED, TIME_SHARED, scenarios, simulate

pytestmark = pytest.mark.tier1


@pytest.mark.parametrize("hp,vp", [(SPACE_SHARED, SPACE_SHARED),
                                   (TIME_SHARED, TIME_SHARED)])
def test_pallas_sweep_matches_jnp_engine(hp, vp):
    scn = scenarios.fig4_scenario(hp, vp)
    res_jnp = jax.jit(simulate)(scn)
    res_pl = jax.jit(simulate)(scn.replace(sweep_impl="pallas"))
    np.testing.assert_allclose(
        np.array(res_jnp.finish_t), np.array(res_pl.finish_t), rtol=1e-5)
    assert int(res_jnp.n_events) == int(res_pl.n_events)


def test_pallas_sweep_federation():
    scn = scenarios.table1_scenario(True).replace(sweep_impl="pallas")
    res = jax.jit(simulate)(scn)
    assert int(res.n_finished) == 25
    assert int(res.n_migrations) == 10
