"""The event_step kernel + Instrument layer + simulate_history driver."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SPACE_SHARED,
    TIME_SHARED,
    Instrument,
    UtilizationTimelineInstrument,
    scenarios,
    simulate,
    simulate_history,
    simulate_instrumented,
    step,
)
from repro.core.energy import PowerModel
from repro.core.pytree import pytree_dataclass

pytestmark = pytest.mark.tier1


def _results_identical(res_a, res_b):
    for f in dataclasses.fields(res_a):
        np.testing.assert_array_equal(
            np.array(getattr(res_a, f.name)), np.array(getattr(res_b, f.name)),
            err_msg=f"SimResult.{f.name} diverged")


# ---------------------------------------------------------------------------
# simulate_history: the fixed-length scan driver
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hp,vp", [(SPACE_SHARED, SPACE_SHARED),
                                   (TIME_SHARED, TIME_SHARED)])
def test_history_result_matches_simulate(hp, vp):
    scn = scenarios.fig4_scenario(hp, vp)
    res = jax.jit(simulate)(scn)
    res_h, hist = jax.jit(simulate_history)(scn)
    _results_identical(res, res_h)
    valid = np.array(hist.valid)
    assert valid.sum() == int(res.n_events)
    # padding rows are inert
    assert (np.array(hist.kind)[~valid] == -1).all()
    assert (np.array(hist.t)[~valid] == 0.0).all()


def test_history_log_contents_fig4():
    """Space/space fig4: 4 completion events at 400/800/1200/1600; the one
    2-core host is fully utilized until the last completion."""
    scn = scenarios.fig4_scenario(SPACE_SHARED, SPACE_SHARED)
    _, hist = jax.jit(simulate_history)(scn)
    v = np.array(hist.valid)
    np.testing.assert_allclose(np.array(hist.t)[v],
                               [400.0, 800.0, 1200.0, 1600.0], rtol=1e-5)
    assert (np.array(hist.kind)[v] == step.K_COMPLETION).all()
    np.testing.assert_allclose(np.array(hist.utilization)[v][:, 0], 1.0,
                               atol=1e-6)
    # accrued CPU cost is monotone along the event log
    cpu = np.array(hist.cpu_cost)[v].sum(axis=1)
    assert (np.diff(cpu) > 0).all()
    # finished counter counts up to 8
    assert np.array(hist.n_finished)[v].tolist() == [2, 4, 6, 8]


def test_history_event_kinds_federation():
    """Federated table1 run must log sensor ticks and migration completions."""
    scn = scenarios.table1_scenario(True)
    _, hist = jax.jit(simulate_history)(scn)
    v = np.array(hist.valid)
    kinds = np.array(hist.kind)[v]
    assert (kinds == step.K_TICK).any()
    assert (kinds == step.K_COMPLETION).any()
    assert (kinds == step.K_MIGRATION).any() or (kinds == step.K_READY).any()


def test_history_energy_snapshots():
    scn = scenarios.fig4_scenario(SPACE_SHARED, SPACE_SHARED).replace(
        power=PowerModel.uniform(1))
    res_h, hist = jax.jit(simulate_history)(scn)
    v = np.array(hist.valid)
    e = np.array(hist.energy_j)[v].sum(axis=1)
    assert (np.diff(e) > 0).all()
    np.testing.assert_allclose(e[-1], float(np.sum(np.array(res_h.energy_j))),
                               rtol=1e-6)


def test_history_vmappable():
    """A campaign of histories: fixed shapes make the event log vmappable."""
    from repro.core import stack_scenarios

    scns = [scenarios.fig4_scenario(hp, vp) for hp in (0, 1) for vp in (0, 1)]
    batched = stack_scenarios(scns)
    res, hist = jax.jit(jax.vmap(simulate_history))(batched)
    assert np.array(hist.valid).shape[0] == 4
    for i in range(4):
        np.testing.assert_array_equal(
            np.array(hist.valid[i]).sum(), int(np.array(res.n_events[i])))


# ---------------------------------------------------------------------------
# Instruments: composability
# ---------------------------------------------------------------------------

def test_utilization_timeline_instrument():
    """The Figure 9/10-style per-DC utilization observable: one class, no
    engine fork."""
    ts = jnp.asarray(np.arange(0.0, 2000.0, 100.0, dtype=np.float32))
    scn = scenarios.fig4_scenario(SPACE_SHARED, SPACE_SHARED).replace(
        instruments=(UtilizationTimelineInstrument(sample_ts=ts),))
    res, out = simulate_instrumented(scn)
    util = np.array(out["utilization"]["utilization"])
    assert util.shape == (len(ts), 1)
    # busy until 1600 (fig4a), idle after
    assert np.allclose(util[np.array(ts) < 1600.0, 0], 1.0, atol=1e-6)
    assert np.allclose(util[np.array(ts) > 1600.0, 0], 0.0, atol=1e-6)
    # attaching an observer does not perturb the simulation
    _results_identical(res, jax.jit(simulate)(scn.replace(instruments=())))


def test_custom_instrument_one_small_class():
    """A new observable is one small class: count events by kind."""

    @pytree_dataclass
    class EventKindCounter(Instrument):
        name = "kind_counter"

        def init(self, scn):
            return jnp.zeros((7,), jnp.int32)

        def post(self, scn, st, ev, aux):
            return st, aux.at[ev.kind].add(1)

        def finalize(self, scn, st, aux):
            return {"counts": aux}

    scn = scenarios.fig4_scenario(SPACE_SHARED, SPACE_SHARED).replace(
        instruments=(EventKindCounter(),))
    res, out = jax.jit(simulate_instrumented)(scn)
    counts = np.array(out["kind_counter"]["counts"])
    assert counts.sum() == int(res.n_events)
    assert counts[step.K_COMPLETION] == 4


def test_instrument_bound_is_a_clock_stop():
    """An instrument bound() must split intervals without changing results."""

    @pytree_dataclass
    class ClockStop(Instrument):
        name = "clock_stop"
        stop_every: jax.Array

        def bound(self, scn, st, aux):
            # next multiple of stop_every strictly after t
            k = jnp.floor(st.t / self.stop_every) + 1
            return k * self.stop_every

        def extra_steps(self, scn):
            # bound() adds clock stops: grow the driver's step budget so the
            # loop cannot silently truncate (step.resolve_max_steps)
            return 64

    scn = scenarios.fig4_scenario(SPACE_SHARED, SPACE_SHARED)
    res = jax.jit(simulate)(scn)
    scn_s = scn.replace(instruments=(
        ClockStop(stop_every=jnp.asarray(150.0, jnp.float32)),))
    res_s = jax.jit(simulate)(scn_s)
    # more events (the stops), same physics and same total accrual
    assert int(res_s.n_events) > int(res.n_events)
    np.testing.assert_allclose(np.array(res.finish_t), np.array(res_s.finish_t),
                               rtol=1e-5)
    np.testing.assert_allclose(float(res.total_cost), float(res_s.total_cost),
                               rtol=1e-5)


def test_duplicate_instrument_names_rejected():
    """Outputs are keyed by name: a silent collision would drop results."""
    ts = jnp.arange(4.0)
    scn = scenarios.fig4_scenario(SPACE_SHARED, SPACE_SHARED).replace(
        instruments=(UtilizationTimelineInstrument(sample_ts=ts),
                     UtilizationTimelineInstrument(sample_ts=ts * 2)))
    with pytest.raises(ValueError, match="duplicate instrument name"):
        simulate_instrumented(scn)


def test_bound_instrument_extra_steps_prevents_truncation():
    """A tight-period clock-stop instrument must not exhaust max_steps."""

    @pytree_dataclass
    class TightStop(Instrument):
        name = "tight_stop"
        stop_every: jax.Array

        def bound(self, scn, st, aux):
            return (jnp.floor(st.t / self.stop_every) + 1) * self.stop_every

        def extra_steps(self, scn):
            return 2000

    scn = scenarios.fig4_scenario(SPACE_SHARED, SPACE_SHARED)
    scn_s = scn.replace(instruments=(
        TightStop(stop_every=jnp.asarray(1.0, jnp.float32)),))
    res = jax.jit(simulate)(scn_s)
    # ~1600 stop events + 4 completions: all work still finishes
    assert int(res.n_finished) == 8
    np.testing.assert_allclose(
        np.array(res.finish_t),
        np.array(jax.jit(simulate)(scn).finish_t), rtol=1e-4)


def test_event_step_is_the_only_loop_body():
    """Guard the tentpole: the drivers may not re-implement the loop body.

    `simulate`, `simulate_trace` and `simulate_history` must all route
    through step.event_step — asserted structurally: engine.py contains no
    policy-sweep or advance calls of its own.
    """
    import inspect

    from repro.core import engine

    src = inspect.getsource(engine)
    assert "cloudlet_rates" not in src
    assert "advance(" not in src
    assert src.count("event_step(scn,") == 2  # while-loop + scan drivers
