"""End-to-end behaviour: the paper's experiments + the full train->serve loop
+ elastic restart, on CPU-sized configs."""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import SPACE_SHARED, TIME_SHARED, scenarios, simulate


def test_fig9_staircase():
    """Space-shared: group g finishes at exactly 1200*(g+1) s (20-min tasks,
    dedicated cores) — paper Figure 9. Scaled to 100 hosts/5 VMs for CI."""
    scn = scenarios.fig9_10_scenario(SPACE_SHARED, n_hosts=100, n_vms=5,
                                     n_groups=4)
    res = jax.jit(simulate)(scn)
    sub = np.array(scn.cloudlets.submit_t)
    fin = np.array(res.finish_t)
    for g in range(4):
        np.testing.assert_allclose(
            fin[sub == g * 600], 1200.0 * (g + 1), rtol=3e-3)


def test_fig10_time_shared_dynamics():
    """Time-shared: first group finishes earlier than steady-state groups;
    last group's turnaround improves as the system drains — Figure 10."""
    scn = scenarios.fig9_10_scenario(TIME_SHARED, n_hosts=100, n_vms=5,
                                     n_groups=6)
    res = jax.jit(simulate)(scn)
    sub = np.array(scn.cloudlets.submit_t)
    fin = np.array(res.finish_t)
    tat = fin - sub
    g_tat = [tat[sub == g * 600].mean() for g in range(6)]
    assert g_tat[0] < g_tat[2]          # early group beats steady state
    assert g_tat[5] < g_tat[2]          # draining improves the tail
    assert int(res.n_finished) == 6 * 5


def test_train_then_serve_roundtrip(tmp_path):
    """Train a small model, checkpoint, restore, serve it — full loop."""
    from repro.ckpt import restore
    from repro.launch.train import run_training
    from repro.models import build_model
    from repro.serving import ServingEngine

    cfg = get_config("internlm2-1.8b", smoke=True)
    out = run_training(cfg, steps=12, global_batch=4, seq_len=32,
                       ckpt_dir=str(tmp_path), ckpt_every=6, log_every=0)
    assert out["steps_run"] == 12
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.train import adamw_init

    (params, _), step = restore(str(tmp_path), (params, adamw_init(params)))
    assert step == 12
    eng = ServingEngine(model, params, n_slots=2, max_len=48)
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab, size=6), max_new_tokens=4)
    reqs = eng.run_until_drained(max_steps=60)
    assert all(r.done for r in reqs)


def test_elastic_restart(tmp_path):
    """Injected failures -> checkpoint restore -> completion (deliverable:
    fault tolerance), with the CloudSim restart plan evaluated."""
    from repro.launch.elastic import ElasticRunner

    cfg = get_config("internlm2-1.8b", smoke=True)
    runner = ElasticRunner(cfg, str(tmp_path), steps=24, global_batch=4,
                           seq_len=32, ckpt_every=6, n_workers=4)
    out = runner.run(fail_at_steps=[10, 17])
    assert out["restarts"] == 2
    kinds = [e["kind"] for e in out["events"]]
    assert kinds == ["failure", "failure", "finished"]
    # resumed from the last checkpoint each time
    assert out["events"][0]["resume_step"] == 6
    assert out["events"][1]["resume_step"] == 12
    assert out["events"][0]["plan"]["choice"] in ("survivors",
                                                  "wait_for_repair")
    assert np.isfinite(out["result"]["final_loss"])


def test_restart_plan_tradeoff():
    """The CloudSim plan flips as repair time varies (sanity of the
    coordinator's decision model)."""
    from repro.launch.elastic import plan_restart

    fast_repair = plan_restart(steps_remaining=100, step_time_s=1.0,
                               n_workers=8, n_survivors=2,
                               repair_time_s=5.0)
    slow_repair = plan_restart(steps_remaining=100, step_time_s=1.0,
                               n_workers=8, n_survivors=2,
                               repair_time_s=10_000.0)
    assert fast_repair.choice == "wait_for_repair"
    assert slow_repair.choice == "survivors"
