"""Checkpoint: roundtrip, atomicity, latest discovery, async, mismatch."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import AsyncSaver, latest_step, restore, save

pytestmark = pytest.mark.tier1


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (4, 8)),
        "nested": {"b": jnp.arange(5, dtype=jnp.float32),
                   "s": jnp.asarray(3, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t)
    out, step = restore(str(tmp_path), jax.tree.map(np.zeros_like, t))
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.array(a), np.array(b))


def test_latest_discovery(tmp_path):
    assert latest_step(str(tmp_path)) is None
    for s in (3, 10, 5):
        save(str(tmp_path), s, _tree(s))
    assert latest_step(str(tmp_path)) == 10
    out, step = restore(str(tmp_path), _tree())
    assert step == 10
    np.testing.assert_array_equal(np.array(out["w"]),
                                  np.array(_tree(10)["w"]))


def test_async_save(tmp_path):
    s = AsyncSaver()
    t = _tree(1)
    s.save(str(tmp_path), 1, t)
    s.save(str(tmp_path), 2, t)  # waits for the first
    s.wait()
    assert latest_step(str(tmp_path)) == 2


def test_shape_mismatch_rejected(tmp_path):
    save(str(tmp_path), 1, _tree())
    bad = _tree()
    bad["w"] = jnp.zeros((2, 2))
    with pytest.raises(ValueError):
        restore(str(tmp_path), bad)


def test_no_partial_checkpoint_visible(tmp_path):
    """tmp dirs never count as checkpoints (atomic publish)."""
    os.makedirs(tmp_path / ".tmp_x" , exist_ok=True)
    assert latest_step(str(tmp_path)) is None
