"""Streaming reductions: folded summaries vs the materialized [N, ...]
reference on a 1024-point grid — bitwise for integer folds (counts,
histogram bins, argbest, values tables), tolerance-bounded for float means
and percentile sketches; chunk-size invariance; the sharded fold path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import run_campaign, scenarios, stack_scenarios
from repro.core.reducers import (
    ArgBestReducer,
    HistogramReducer,
    MeanReducer,
    SumReducer,
    ValuesReducer,
)

pytestmark = [
    pytest.mark.tier1,
    pytest.mark.filterwarnings("error:Some donated buffers were not usable"),
]

N = 1024
HIST_LO, HIST_HI, HIST_BINS = 0.0, 8000.0, 64

# one reducer dict reused everywhere: reducers are static jit args, so every
# test folding with these at the same chunk size shares ONE compiled program
REDUCE = {
    "events": SumReducer("n_events"),
    "mt": MeanReducer("mean_turnaround"),
    "hist": HistogramReducer("mean_turnaround", HIST_LO, HIST_HI,
                             bins=HIST_BINS, qs=(0.5, 0.9, 0.99)),
    "best": ArgBestReducer("mean_turnaround"),
    "vals": ValuesReducer("mean_turnaround", n_slots=N),
}


@pytest.fixture(scope="module")
def grid():
    """1024-point fig4 grid: policy combos x workload scale, with the
    materialized reference results."""
    base = [scenarios.fig4_scenario(hp, vp) for hp in (0, 1) for vp in (0, 1)]
    rows = [
        s.replace(cloudlets=s.cloudlets.replace(
            length_mi=s.cloudlets.length_mi * (1.0 + 0.02 * (i % 37))
        ))
        for i, s in enumerate(base * (N // 4))
    ]
    batched = stack_scenarios(rows)
    ref = run_campaign(batched, chunk_size=128)
    return batched, ref


def _ref_hist_counts(values):
    width = (HIST_HI - HIST_LO) / HIST_BINS
    idx = np.clip(((values - HIST_LO) / width).astype(np.int32),
                  0, HIST_BINS - 1)
    return np.bincount(idx, minlength=HIST_BINS).astype(np.int32)


def test_folded_matches_materialized(grid):
    batched, ref = grid
    out = run_campaign(batched, chunk_size=128, reduce=REDUCE)
    mt = np.array(ref.mean_turnaround)

    # integer folds are bitwise
    assert int(out["events"]) == int(np.array(ref.n_events).sum())
    np.testing.assert_array_equal(np.array(out["vals"]["values"]), mt)
    assert bool(out["vals"]["filled"].all())
    np.testing.assert_array_equal(np.array(out["hist"]["counts"]),
                                  _ref_hist_counts(mt))

    # argbest: value + index + the winning policy row itself
    best = int(np.argmin(mt))
    assert int(out["best"]["index"]) == best
    assert float(out["best"]["value"]) == mt[best]
    want_row = jax.tree.map(lambda l: l[best], batched.policy)
    for got, want in zip(jax.tree.leaves(out["best"]["policy"]),
                         jax.tree.leaves(want_row)):
        np.testing.assert_array_equal(np.array(got), np.array(want))

    # float mean/std to rounding; histogram quantiles to one bin width
    assert int(out["mt"]["n"]) == N
    np.testing.assert_allclose(float(out["mt"]["mean"]), mt.mean(), rtol=1e-5)
    np.testing.assert_allclose(float(out["mt"]["std"]), mt.std(), rtol=1e-3)
    width = (HIST_HI - HIST_LO) / HIST_BINS
    for q in (0.5, 0.9, 0.99):
        assert abs(float(out["hist"][f"q{q:g}"]) - np.quantile(mt, q)) <= width


def test_chunk_size_invariance(grid):
    """Integer folds must be bitwise identical for any chunking — including
    a ragged trailing chunk (1024 = 5*192 + 64)."""
    batched, _ = grid
    a = run_campaign(batched, chunk_size=128, reduce=REDUCE)
    b = run_campaign(batched, chunk_size=192, reduce=REDUCE)
    assert int(a["events"]) == int(b["events"])
    np.testing.assert_array_equal(np.array(a["vals"]["values"]),
                                  np.array(b["vals"]["values"]))
    np.testing.assert_array_equal(np.array(a["hist"]["counts"]),
                                  np.array(b["hist"]["counts"]))
    assert int(a["best"]["index"]) == int(b["best"]["index"])
    assert float(a["best"]["value"]) == float(b["best"]["value"])
    np.testing.assert_allclose(float(a["mt"]["mean"]), float(b["mt"]["mean"]),
                               rtol=1e-6)


def test_sharded_fold_matches(grid):
    """The shard_map fold on a 1-device mesh is bitwise the local fold."""
    from jax.sharding import Mesh

    batched, ref = grid
    mesh = Mesh(jax.devices()[:1], ("data",))
    out = run_campaign(batched, chunk_size=128, mesh=mesh, reduce=REDUCE)
    np.testing.assert_array_equal(np.array(out["vals"]["values"]),
                                  np.array(ref.mean_turnaround))
    np.testing.assert_array_equal(
        np.array(out["hist"]["counts"]),
        _ref_hist_counts(np.array(ref.mean_turnaround)),
    )
    assert int(out["best"]["index"]) == int(np.argmin(
        np.array(ref.mean_turnaround)))


def test_single_reducer_form():
    """A bare reducer (not a dict) returns its summary directly."""
    batched = stack_scenarios([scenarios.fig4_scenario(0, 0)] * 4)
    out = run_campaign(batched, reduce=SumReducer("n_finished"))
    assert int(out) == 4 * 8


def test_argbest_max_mode(grid):
    batched, ref = grid
    out = run_campaign(batched, chunk_size=128,
                       reduce=ArgBestReducer("mean_turnaround", mode="max"))
    mt = np.array(ref.mean_turnaround)
    assert int(out["index"]) == int(np.argmax(mt))
    assert float(out["value"]) == mt.max()


def test_reducer_validation():
    batched = stack_scenarios([scenarios.fig4_scenario(0, 0)] * 2)
    with pytest.raises(ValueError, match="unknown SimResult field"):
        run_campaign(batched, reduce=SumReducer("not_a_field"))
    with pytest.raises(ValueError, match="one scalar per scenario row"):
        run_campaign(batched, reduce=SumReducer(lambda r: r.turnaround))
    with pytest.raises(ValueError, match="empty histogram range"):
        HistogramReducer("makespan", 1.0, 1.0)
    with pytest.raises(ValueError, match="mode"):
        ArgBestReducer("makespan", mode="best")
    with pytest.raises(TypeError, match="CampaignReducer"):
        run_campaign(batched, reduce={"x": jnp.sum})
