"""Execute every ```python block in the user-facing docs.

The guarantee USER_GUIDE.md advertises — "every code block on this page
runs" — is enforced here: each documented file's fenced ``python`` blocks
are executed top to bottom in one shared namespace (so later blocks can use
names earlier blocks defined, exactly as a reader following along would).
A block whose first line is ``# doc: no-exec`` is display-only (e.g. shell
output or a multi-device sketch) and is skipped.

API.md's field tables are checked separately by scripts/check_docs.py (the
CI docs-drift gate); this file only runs code.
"""
import os
import re

import pytest

pytestmark = pytest.mark.tier1

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ("README.md", os.path.join("docs", "USER_GUIDE.md"))

_FENCE = re.compile(r"^```python[ \t]*\n(.*?)^```[ \t]*$",
                    re.MULTILINE | re.DOTALL)
_SKIP = "# doc: no-exec"


def _blocks(path):
    with open(os.path.join(ROOT, path)) as f:
        text = f.read()
    out = []
    for m in _FENCE.finditer(text):
        body = m.group(1)
        line = text.count("\n", 0, m.start()) + 2   # first line inside fence
        out.append((line, body))
    return out


def test_docs_exist_and_have_code():
    for path in DOCS:
        assert os.path.exists(os.path.join(ROOT, path)), f"{path} missing"
    assert _blocks(os.path.join("docs", "USER_GUIDE.md")), \
        "USER_GUIDE.md has no python blocks to verify"


@pytest.mark.parametrize("path", DOCS)
def test_doc_code_blocks_execute(path):
    ns = {}
    ran = 0
    for line, body in _blocks(path):
        if body.lstrip().startswith(_SKIP):
            continue
        try:
            exec(compile(body, f"{path}:{line}", "exec"), ns)
        except Exception as e:   # noqa: BLE001 — reraise with doc location
            raise AssertionError(
                f"doc block at {path}:{line} failed: {e!r}\n---\n{body}"
            ) from e
        ran += 1
    assert ran > 0, f"{path} has no executable python blocks"
