"""simulate / simulate_trace equivalence — the property the Instrument
refactor guarantees by construction.

The trace is a pure observer (no extra clock stops: mid-interval progress is
interpolated exactly under piecewise-constant rates, DESIGN.md §2), so a
traced run must return a bit-identical ``SimResult`` — including the
``cpu_cost`` / ``bw_cost`` / ``energy_j`` fields the pre-refactor trace
driver silently dropped.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SPACE_SHARED,
    TIME_SHARED,
    scenarios,
    simulate,
    simulate_trace,
)
from repro.core.energy import PowerModel, Topology

pytestmark = pytest.mark.tier1


def _assert_results_identical(res_a, res_b):
    for f in dataclasses.fields(res_a):
        a, b = getattr(res_a, f.name), getattr(res_b, f.name)
        np.testing.assert_array_equal(
            np.array(a), np.array(b), err_msg=f"SimResult.{f.name} diverged"
        )


@pytest.mark.parametrize("hp", [SPACE_SHARED, TIME_SHARED])
@pytest.mark.parametrize("vp", [SPACE_SHARED, TIME_SHARED])
def test_trace_matches_simulate_fig4(hp, vp):
    scn = scenarios.fig4_scenario(hp, vp)
    res = jax.jit(simulate)(scn)
    ts = jnp.asarray(np.arange(0.0, 2000.0, 123.0, dtype=np.float32))
    res_t, prog = simulate_trace(scn, ts)
    _assert_results_identical(res, res_t)
    assert prog.shape == (len(ts), scn.cloudlets.n_cloudlets)


@pytest.mark.parametrize("vp", [SPACE_SHARED, TIME_SHARED])
def test_trace_matches_simulate_fig9_10(vp):
    scn = scenarios.fig9_10_scenario(vp, n_hosts=60, n_vms=6, n_groups=3)
    res = jax.jit(simulate)(scn)
    ts = jnp.asarray(np.arange(0.0, 4000.0, 250.0, dtype=np.float32))
    res_t, _ = simulate_trace(scn, ts)
    _assert_results_identical(res, res_t)
    # the seed engine dropped these on the trace path; they must be nonzero
    assert float(np.sum(np.array(res_t.cpu_cost))) > 0
    assert float(np.sum(np.array(res_t.bw_cost))) > 0


def test_trace_matches_simulate_federated_with_energy():
    """Migration + sensor ticks + power model: every accrual path exercised."""
    scn = scenarios.table1_scenario(True).replace(
        power=PowerModel.uniform(3),
        topology=Topology.uniform(3, latency_s=5.0, bw_mbps=50.0),
    )
    res = jax.jit(simulate)(scn)
    ts = jnp.asarray(np.arange(0.0, 9000.0, 500.0, dtype=np.float32))
    res_t, prog = simulate_trace(scn, ts)
    _assert_results_identical(res, res_t)
    assert float(np.sum(np.array(res_t.energy_j))) > 0
    # progress is monotone in sample time
    assert (np.diff(np.array(prog), axis=0) >= -1e-5).all()


def test_trace_matches_simulate_live_migration():
    """Live migration (MigrationInstrument attached, DESIGN.md §8): the
    traced and history drivers stay bit-identical to ``simulate`` — cost,
    energy, per-VM ``vm_dc`` and ``n_migrations`` included — while VMs
    actually move at runtime."""
    from repro.core import simulate_history

    scn = scenarios.consolidation_scenario()
    res = jax.jit(simulate)(scn)
    assert int(res.n_migrations) == 4, "live moves must actually happen"
    ts = jnp.asarray(np.arange(0.0, 2500.0, 111.0, dtype=np.float32))
    res_t, prog = simulate_trace(scn, ts)
    _assert_results_identical(res, res_t)
    assert float(np.sum(np.array(res_t.energy_j))) > 0
    assert (np.diff(np.array(prog), axis=0) >= -1e-5).all()
    res_h, hist = jax.jit(simulate_history)(scn)
    _assert_results_identical(res, res_h)


def test_trace_matches_simulate_with_failures():
    """Host failures firing (DESIGN.md §9): revocation — eviction, rollback,
    evacuation, downtime accrual — stays a pure engine semantic; the traced
    and history drivers remain bit-identical to ``simulate``, SLA fields
    included."""
    from repro.core import simulate_history

    for scn, want_evac in (
        (scenarios.evacuation_scenario(), True),
        (scenarios.evacuation_scenario(
            evacuation=False, ckpt_interval=3.0e38), False),
    ):
        res = jax.jit(simulate)(scn)
        if want_evac:
            assert int(res.n_evacuations) == 2, "drain must actually happen"
        else:
            assert float(res.downtime) > 0, "failure must actually bite"
        ts = jnp.asarray(np.arange(0.0, 1200.0, 77.0, dtype=np.float32))
        res_t, prog = simulate_trace(scn, ts)
        _assert_results_identical(res, res_t)
        dprog = np.diff(np.array(prog), axis=0)
        if want_evac:
            # stop-and-copy preserves progress: monotone samples
            assert (dprog >= -1e-5).all()
        else:
            # restart-from-zero is *visible* in the trace: progress drops
            assert dprog.min() < -0.1
        res_h, hist = jax.jit(simulate_history)(scn)
        _assert_results_identical(res, res_h)
        # the failure edge appears in the event log (the repair is scheduled
        # past both runs' completion, so the loop never reaches it)
        kinds = np.array(hist.kind)[np.array(hist.valid)]
        from repro.core.step import K_FAILURE
        assert (kinds == K_FAILURE).sum() == 1


def test_trace_matches_simulate_randomized():
    """Property over random workloads: traced SimResult == untraced, all
    fields, across seeds x policy combos (no hypothesis dependency)."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        n_vms = int(rng.integers(1, 5))
        n_cl = n_vms + int(rng.integers(0, 6))
        hosts = scenarios.uniform_hosts(
            1, int(rng.integers(1, 4)), cores=int(rng.integers(1, 3)),
            mips=float(rng.uniform(10, 200)), ram_mb=4096.0)
        vms = scenarios.uniform_vms(
            n_vms, cores=1, mips=float(rng.uniform(10, 200)), ram_mb=256.0)
        cl_vm = np.concatenate(
            [np.arange(n_vms), rng.integers(0, n_vms, n_cl - n_vms)])
        cls = scenarios.make_cloudlets(
            cl_vm, rng.uniform(100, 5000, n_cl), rng.uniform(0, 50, n_cl))
        scn = scenarios.Scenario(
            hosts=hosts, vms=vms, cloudlets=cls,
            market=scenarios.uniform_market(1),
            policy=scenarios.make_policy(
                host_policy=int(rng.integers(0, 2)),
                vm_policy=int(rng.integers(0, 2)),
                horizon=1e6,
            ),
        )
        res = jax.jit(simulate)(scn)
        ts = jnp.asarray(
            np.sort(rng.uniform(0, 1000, 7)).astype(np.float32))
        res_t, _ = simulate_trace(scn, ts)
        _assert_results_identical(res, res_t)
