"""Engine invariants — property-based (hypothesis) + determinism/vmap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    SPACE_SHARED,
    TIME_SHARED,
    Scenario,
    scenarios,
    simulate,
    stack_scenarios,
    run_campaign,
)

pytestmark = pytest.mark.tier1


def _random_scenario(rng: np.random.Generator, hp, vp) -> Scenario:
    n_hosts = int(rng.integers(1, 4))
    n_vms = int(rng.integers(1, 5))
    n_extra = int(rng.integers(0, 6))
    hosts = scenarios.uniform_hosts(
        1, n_hosts, cores=int(rng.integers(1, 3)),
        mips=float(rng.uniform(10, 200)), ram_mb=4096.0)
    vms = scenarios.uniform_vms(
        n_vms, cores=1, mips=float(rng.uniform(10, 200)), ram_mb=256.0)
    # every VM gets >=1 cloudlet: an idle VM legitimately holds its cores
    # forever under space-sharing, starving later VMs (Fig 4a semantics)
    n_cl = n_vms + n_extra
    cl_vm = np.concatenate([np.arange(n_vms),
                            rng.integers(0, n_vms, n_extra)])
    cls = scenarios.make_cloudlets(
        cl_vm,
        rng.uniform(100, 5000, n_cl),
        rng.uniform(0, 50, n_cl),
        input_mb=0.0, output_mb=0.0)
    return Scenario(
        hosts=hosts, vms=vms, cloudlets=cls,
        market=scenarios.uniform_market(1),
        policy=scenarios.make_policy(host_policy=hp, vm_policy=vp,
                                     horizon=1e6))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    hp=st.sampled_from([SPACE_SHARED, TIME_SHARED]),
    vp=st.sampled_from([SPACE_SHARED, TIME_SHARED]),
)
def test_engine_invariants(seed, hp, vp):
    rng = np.random.default_rng(seed)
    scn = _random_scenario(rng, hp, vp)
    res = jax.jit(simulate)(scn)

    fin = np.array(res.finish_t)
    placed = np.array(res.vm_placed)
    failed = np.array(res.vm_failed)
    cl_vm = np.array(scn.cloudlets.vm)
    submit = np.array(scn.cloudlets.submit_t)
    length = np.array(scn.cloudlets.length_mi)
    vmips = np.array(scn.vms.mips)

    done = np.isfinite(fin) & (fin < 1e30)
    # every cloudlet whose VM was placed must finish (work conservation:
    # positive rates guarantee progress; horizon is generous)
    for i in range(len(fin)):
        if placed[cl_vm[i]]:
            assert done[i], f"cloudlet {i} starved"
        if failed[cl_vm[i]]:
            assert not done[i]
    # physics: never faster than the VM's requested per-core MIPS (the
    # time-shared VMM is a fluid pool — CloudSim semantics — so the host
    # per-core MIPS is not a bound, but the VM's request always is)
    min_time = length / vmips[cl_vm]
    assert (fin[done] >= submit[done] + min_time[done] * (1 - 1e-3) - 1.0).all()
    # event budget respected
    assert int(res.n_events) <= 4 * (len(fin) + len(vmips)) + 260


def test_determinism_and_vmap_consistency():
    rng = np.random.default_rng(7)
    scn = _random_scenario(rng, TIME_SHARED, TIME_SHARED)
    r1 = jax.jit(simulate)(scn)
    r2 = jax.jit(simulate)(scn)
    np.testing.assert_array_equal(np.array(r1.finish_t), np.array(r2.finish_t))

    batched = stack_scenarios([scn, scn, scn])
    rb = run_campaign(batched)
    for i in range(3):
        np.testing.assert_allclose(
            np.array(rb.finish_t[i]), np.array(r1.finish_t), rtol=1e-6)


def test_scale_invariance():
    """Doubling MIPS and MI leaves completion times unchanged."""
    rng = np.random.default_rng(3)
    scn = _random_scenario(rng, SPACE_SHARED, TIME_SHARED)
    scn2 = scn.replace(
        hosts=scn.hosts.replace(mips=scn.hosts.mips * 2),
        vms=scn.vms.replace(mips=scn.vms.mips * 2),
        cloudlets=scn.cloudlets.replace(
            length_mi=scn.cloudlets.length_mi * 2),
    )
    r1 = jax.jit(simulate)(scn)
    r2 = jax.jit(simulate)(scn2)
    f1, f2 = np.array(r1.finish_t), np.array(r2.finish_t)
    done = np.isfinite(f1) & (f1 < 1e30)
    np.testing.assert_allclose(f1[done], f2[done], rtol=1e-2)


def test_market_accounting():
    """RAM/storage billed at creation; CPU cost proportional to run time."""
    scn = scenarios.fig4_scenario(SPACE_SHARED, SPACE_SHARED)
    res = jax.jit(simulate)(scn)
    # 2 VMs x 1024 MB x 0.05 $/MB
    np.testing.assert_allclose(float(np.sum(res.ram_cost)), 2 * 1024 * 0.05,
                               rtol=1e-5)
    # cpu: 8 tasks x 400s x 3 $/s (space/space: every task runs 400s)
    np.testing.assert_allclose(float(np.sum(res.cpu_cost)), 8 * 400 * 3.0,
                               rtol=3e-3)


def test_horizon_cuts_simulation():
    scn = scenarios.fig4_scenario(SPACE_SHARED, SPACE_SHARED)
    scn = scn.replace(policy=scn.policy.replace(
        horizon=jnp.asarray(500.0, jnp.float32)))
    res = jax.jit(simulate)(scn)
    # only the first two tasks (finish at 400) complete before t=500
    assert int(res.n_finished) == 2
    assert float(res.end_t) <= 500.0 + 1e-3
