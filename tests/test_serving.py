"""Serving engine + CloudSim-driven scheduler."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SPACE_SHARED, TIME_SHARED
from repro.models import build_model
from repro.serving import ServingEngine, choose_policy, queue_scenario
from repro.serving.scheduler import Request


def _engine(policy=SPACE_SHARED, slots=2, replan=0):
    cfg = get_config("internlm2-1.8b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, ServingEngine(model, params, n_slots=slots, max_len=64,
                              policy=policy, replan_every=replan)


@pytest.mark.parametrize("policy", [SPACE_SHARED, TIME_SHARED])
def test_engine_drains(policy):
    cfg, eng = _engine(policy)
    rng = np.random.default_rng(0)
    for _ in range(5):
        eng.submit(rng.integers(0, cfg.vocab, size=8), max_new_tokens=5)
    reqs = eng.run_until_drained(max_steps=200)
    assert all(r.done for r in reqs)
    assert all(r.generated >= 5 for r in reqs)


def test_space_shared_is_fcfs_exclusive():
    cfg, eng = _engine(SPACE_SHARED, slots=1)
    rng = np.random.default_rng(0)
    r1 = eng.submit(rng.integers(0, cfg.vocab, size=4), max_new_tokens=4)
    r2 = eng.submit(rng.integers(0, cfg.vocab, size=4), max_new_tokens=4)
    eng.run_until_drained(max_steps=100)
    assert r1.finish_time < r2.finish_time  # strict FCFS on one slot


def test_choose_policy_prefers_space_for_uniform_short():
    """For equal-length jobs, space-shared has the lower mean TAT (the
    classic M/D result the paper's Fig 9/10 illustrates)."""
    reqs = [Request(rid=i, arrival=0.0, prompt_len=4, max_new_tokens=64)
            for i in range(8)]
    pol, metrics = choose_policy(reqs, n_slots=2, tokens_per_sec=100.0)
    assert pol == SPACE_SHARED
    assert metrics["space"]["mean_tat"] <= metrics["time"]["mean_tat"]
    # makespan identical under work conservation
    assert np.isclose(metrics["space"]["makespan"],
                      metrics["time"]["makespan"], rtol=0.01)


def test_queue_scenario_shapes():
    reqs = [Request(rid=0, arrival=0.0, prompt_len=4, max_new_tokens=10)]
    scn = queue_scenario(reqs, n_slots=4, tokens_per_sec=50.0,
                         vm_policy=TIME_SHARED)
    assert scn.cloudlets.n_cloudlets == 1
    assert float(scn.hosts.mips[0, 0]) == 50.0


# --- capacity planning (repro.serving.capacity, DESIGN.md §14) ---

def test_kv_bytes_per_token_counts_attention_layers_only():
    from repro.serving import capacity

    cfg = get_config("internlm2-1.8b")
    n_attn = capacity.n_attn_layers(cfg)
    assert 0 < n_attn <= cfg.n_layers
    expect = 2 * n_attn * cfg.n_kv_heads * cfg.d_head * (
        2 if cfg.dtype in ("bfloat16", "float16") else 4)
    assert capacity.kv_bytes_per_token(cfg) == expect


def test_kv_blocks_per_device_monotone_in_hbm():
    from repro.serving import capacity

    cfg = get_config("internlm2-1.8b")
    small = capacity.kv_blocks_per_device(cfg, 16e9)
    large = capacity.kv_blocks_per_device(cfg, 80e9)
    assert 0 < small < large
    # weights alone overflow a tiny device: zero blocks, not negative
    assert capacity.kv_blocks_per_device(cfg, 1e6) == 0
    # halving block_tokens doubles the block count (same byte budget)
    b16 = capacity.kv_blocks_per_device(cfg, 80e9, block_tokens=16)
    b8 = capacity.kv_blocks_per_device(cfg, 80e9, block_tokens=8)
    assert abs(b8 - 2 * b16) <= 1
