"""Search driver: knob-space helpers, campaign building, random search vs
the exhaustive reference, and successive halving's one-compiled-program
property (the runtime counterpart of simlint R5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import campaign, run_campaign, scenarios
from repro.core.reducers import ValuesReducer
from repro.core.search import (
    build_campaign,
    grid_params,
    random_search,
    sample_params,
    successive_halving,
)

pytestmark = [
    pytest.mark.tier1,
    pytest.mark.filterwarnings("error:Some donated buffers were not usable"),
]


def test_grid_params_cartesian():
    g = grid_params({"a": [1.0, 2.0], "b": [10.0, 20.0, 30.0]})
    assert all(v.shape == (6,) for v in g.values())
    combos = set(zip(np.array(g["a"]).tolist(), np.array(g["b"]).tolist()))
    assert combos == {(a, b) for a in (1.0, 2.0) for b in (10.0, 20.0, 30.0)}
    with pytest.raises(ValueError, match="empty"):
        grid_params({})


def test_sample_params_support_and_determinism():
    space = {"x": [1.0, 2.0, 4.0], "y": [0, 1]}
    a = sample_params(jax.random.PRNGKey(3), space, 64)
    b = sample_params(jax.random.PRNGKey(3), space, 64)
    assert set(np.array(a["x"]).tolist()) <= {1.0, 2.0, 4.0}
    assert set(np.array(a["y"]).tolist()) <= {0, 1}
    np.testing.assert_array_equal(np.array(a["x"]), np.array(b["x"]))


def test_build_campaign_policy_knobs():
    tmpl = scenarios.fig4_scenario(0, 0)
    params = {"host_policy": jnp.asarray([0, 0, 1, 1]),
              "vm_policy": jnp.asarray([0, 1, 0, 1])}
    batched = build_campaign(tmpl, params)
    np.testing.assert_array_equal(np.array(batched.policy.host_policy),
                                  [0, 0, 1, 1])
    # untouched template leaves broadcast along the campaign axis
    assert jax.tree.leaves(batched.cloudlets)[0].shape[0] == 4


def test_build_campaign_extras_need_instantiate():
    tmpl = scenarios.fig4_scenario(0, 0)
    params = {"length_scale": jnp.asarray([1.0, 2.0])}
    with pytest.raises(ValueError, match="instantiate"):
        build_campaign(tmpl, params)

    def instantiate(template, extras, n, key):
        cl = jax.vmap(
            lambda s: template.cloudlets.replace(
                length_mi=template.cloudlets.length_mi * s)
        )(extras["length_scale"])
        return {"cloudlets": cl}

    batched = build_campaign(tmpl, params, instantiate=instantiate)
    res = run_campaign(batched)
    # doubling cloudlet length doubles fig4 turnaround
    np.testing.assert_allclose(np.array(res.mean_turnaround)[1],
                               2 * np.array(res.mean_turnaround)[0],
                               rtol=1e-6)


def test_random_search_matches_exhaustive_reference():
    tmpl = scenarios.fig4_scenario(0, 0)
    space = {"host_policy": [0, 1], "vm_policy": [0, 1]}
    out = random_search(tmpl, space, key=jax.random.PRNGKey(0), n=16,
                        metric="mean_turnaround", chunk_size=8)
    ref = run_campaign(
        build_campaign(tmpl, out["params"]), chunk_size=8,
        reduce=ValuesReducer("mean_turnaround", n_slots=16),
    )
    np.testing.assert_array_equal(np.array(out["values"]),
                                  np.array(ref["values"]))
    assert out["best_index"] == int(np.argmin(np.array(out["values"])))
    assert float(out["best_value"]) == np.array(out["values"]).min()
    # fig4: space/space dominates — the best draw must be one of its rows
    assert int(out["best_params"]["host_policy"]) == 0
    assert int(out["best_params"]["vm_policy"]) == 0


def test_successive_halving_finds_optimum_and_reuses_program():
    tmpl = scenarios.fig4_scenario(0, 0)
    space = {"host_policy": [0, 1], "vm_policy": [0, 1]}
    kw = dict(n0=8, fidelities=(4000.0, 8000.0), eta=2,
              metric="mean_turnaround", chunk_size=4)
    size = campaign._run_chunk_fold._cache_size
    before = size()
    out = successive_halving(tmpl, space, key=jax.random.PRNGKey(1), **kw)
    first = size() - before
    assert first <= 1, "rungs forked the compiled fold program"
    # a fresh search with different candidate values compiles nothing new
    out2 = successive_halving(tmpl, space, key=jax.random.PRNGKey(9), **kw)
    assert size() - before == first, "knob values leaked into the jit cache"

    for res in (out, out2):
        assert int(res["best_params"]["host_policy"]) == 0
        assert int(res["best_params"]["vm_policy"]) == 0
    ns = [r["candidates"].shape[0] for r in out["rungs"]]
    assert ns == [8, 4]
    assert [r["fidelity"] for r in out["rungs"]] == [4000.0, 8000.0]
    # survivors of rung 0 are its top half
    v0 = np.array(out["rungs"][0]["values"])
    picked = set(np.array(out["rungs"][1]["candidates"]).tolist())
    assert picked == set(np.argsort(v0)[:4].tolist())


def test_successive_halving_validation():
    tmpl = scenarios.fig4_scenario(0, 0)
    space = {"host_policy": [0, 1]}
    with pytest.raises(ValueError, match="not a Policy field"):
        successive_halving(tmpl, space, key=jax.random.PRNGKey(0), n0=4,
                           fidelities=(1.0,), fidelity_knob="mtbf")
    with pytest.raises(ValueError, match="cannot also be"):
        successive_halving(tmpl, {"horizon": [1.0]},
                           key=jax.random.PRNGKey(0), n0=4, fidelities=(1.0,))
    with pytest.raises(ValueError, match="cannot halve"):
        successive_halving(tmpl, space, key=jax.random.PRNGKey(0), n0=2,
                           fidelities=(1.0, 2.0, 3.0))
