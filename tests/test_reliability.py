"""Host failures + SLA-driven reliability (DESIGN.md §9).

Covers the revocation half of the simulator the PR-4 suite could not: host
failures are the first event that *takes grants back*, so these tests pin

* the seeded outage-schedule generator (determinism, disjoint sorted
  windows, the MTBF = ∞ control),
* failure semantics — eviction, checkpoint rollback arithmetic, re-queue
  through the creation path, downtime accounting,
* the ``vm_failed`` contract: terminal creation rejection is *never*
  resurrected by a repair, and transient host-down eviction never sets it,
* proactive evacuation (progress preserved, deadlines met) vs the
  restart-from-zero control — in the same compiled program, and
* a vmapped MTBF x policy campaign row-matching a Python loop bitwise.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    INF,
    broadcast_campaign,
    run_campaign,
    scenarios,
    simulate,
    workload,
)

pytestmark = pytest.mark.tier1


def _mk(b=0):
    return jax.random.PRNGKey(17 + b)


# ---------------------------------------------------------------------------
# outage-schedule generator
# ---------------------------------------------------------------------------

def test_host_outages_deterministic_and_sorted():
    a = workload.host_outages(_mk(), 2, 3, 4, 500.0, 200.0)
    b = workload.host_outages(_mk(), 2, 3, 4, 500.0, 200.0)
    np.testing.assert_array_equal(np.array(a.fail_t), np.array(b.fail_t))
    np.testing.assert_array_equal(np.array(a.repair_t), np.array(b.repair_t))
    fail, repair = np.array(a.fail_t), np.array(a.repair_t)
    # windows are disjoint and sorted: fail_k < repair_k <= fail_{k+1}
    assert (repair > fail).all()
    assert (fail[..., 1:] >= repair[..., :-1]).all()


def test_host_outages_mtbf_inf_is_all_padding():
    out = workload.host_outages(_mk(), 2, 2, 3, INF, 200.0)
    assert (np.array(out.fail_t) >= float(INF)).all()
    assert not bool(np.any(np.array(out.down_at(1e30))))


def test_host_outages_vmappable_over_rate():
    mtbfs = jnp.asarray([100.0, 1000.0, float(INF)], jnp.float32)
    outs = jax.vmap(
        lambda m: workload.host_outages(_mk(), 1, 2, 2, m, 50.0)
    )(mtbfs)
    assert outs.fail_t.shape == (3, 1, 2, 2)
    # same key -> same unit draws, scaled by MTBF: later first failure
    first = np.array(outs.fail_t)[:, 0, 0, 0]
    assert first[0] < first[1] < first[2]


# ---------------------------------------------------------------------------
# failure semantics: eviction, rollback, re-queue
# ---------------------------------------------------------------------------

def _one_host_outage_scenario(ckpt=INF, fail_at=100.0, repair_after=400.0,
                              task_mi=300_000.0, federation=False, n_dc=1,
                              deadline=3.0e38):
    """One 1-core host (+ optional empty peer DC), one VM, one cloudlet."""
    hosts = scenarios.uniform_hosts(n_dc, 1, cores=1, mips=1000.0,
                                    ram_mb=1024.0, storage_mb=2_000_000.0)
    vms = scenarios.uniform_vms(1, dc=0, ram_mb=512.0, storage_mb=1024.0,
                                image_mb=1024.0)
    cls = scenarios.make_cloudlets(np.array([0]), np.array([task_mi]),
                                   np.zeros(1), input_mb=0.0, output_mb=0.0,
                                   deadline=deadline)
    out = workload.no_outages(n_dc, 1, 1)
    out = out.replace(
        fail_t=out.fail_t.at[0, 0, 0].set(fail_at),
        repair_t=out.repair_t.at[0, 0, 0].set(fail_at + repair_after))
    pol = scenarios.make_policy(
        core_reserving=True, federation=federation, ckpt_interval=ckpt,
        migration_fixed_s=30.0, interdc_bw_mbps=100.0, horizon=50_000.0)
    return scenarios.Scenario(
        hosts=hosts, vms=vms, cloudlets=cls,
        market=scenarios.uniform_market(n_dc), policy=pol, outages=out,
        max_steps=200)


def test_restart_from_zero_rollback():
    """ckpt = INF: the outage costs fail_at seconds of work + the outage."""
    scn = _one_host_outage_scenario(ckpt=INF)
    res = jax.jit(simulate)(scn)
    # 100s done and lost; host back at 500; full 300s re-run -> 800
    assert int(res.n_finished) == 1
    np.testing.assert_allclose(float(res.finish_t[0]), 800.0, atol=0.5)
    np.testing.assert_allclose(float(res.downtime), 400.0, atol=0.5)
    assert int(res.n_evacuations) == 0
    assert not bool(res.vm_failed[0])


def test_checkpoint_rollback_keeps_completed_intervals():
    """ckpt = 30k MI: only work past the last checkpoint is re-done."""
    scn = _one_host_outage_scenario(ckpt=30_000.0)
    res = jax.jit(simulate)(scn)
    # 100s = 100k MI done; kept floor(100k/30k)*30k = 90k; resume at 500
    # with 210k MI left -> finish at 710
    np.testing.assert_allclose(float(res.finish_t[0]), 710.0, atol=0.5)


def test_requeue_prefers_federation_peer():
    """With an empty peer DC, the evicted VM re-places immediately there —
    downtime is just the recovery transfer, not the outage."""
    scn = _one_host_outage_scenario(ckpt=INF, federation=True, n_dc=2)
    res = jax.jit(simulate)(scn)
    transfer = 30.0 + 1024.0 / 100.0                    # fixed + image/bw
    np.testing.assert_allclose(float(res.downtime), transfer, atol=0.5)
    # restart from zero on the peer right after the transfer
    np.testing.assert_allclose(
        float(res.finish_t[0]), 100.0 + transfer + 300.0, atol=0.5)
    assert int(np.array(res.vm_dc)[0]) == 1
    assert int(res.n_migrations) == 1


def test_sla_violation_accounting():
    """Deadlines on both sides of the failure-stretched finish time."""
    hit = jax.jit(simulate)(_one_host_outage_scenario(deadline=900.0))
    miss = jax.jit(simulate)(_one_host_outage_scenario(deadline=700.0))
    assert int(hit.sla_violations) == 0
    assert int(miss.sla_violations) == 1
    # an unfinished cloudlet with a real deadline also violates
    never = _one_host_outage_scenario(deadline=700.0, repair_after=1e9)
    res = jax.jit(simulate)(never.replace(
        policy=never.policy.replace(horizon=jnp.float32(2000.0))))
    assert int(res.n_finished) == 0
    assert int(res.sla_violations) == 1


def test_vm_failed_terminal_not_resurrected_by_repair():
    """The satellite regression: a creation rejected outright (vm_failed)
    stays dead across a repair that frees capacity; a failure-evicted VM
    (vm_evicted) comes back.  The two states must never blur."""
    hosts = scenarios.uniform_hosts(1, 1, cores=1, mips=1000.0,
                                    ram_mb=1024.0, storage_mb=2_000_000.0)
    # row A requests at 0 (placed, then evicted at 10); row B requests at 50
    # mid-outage, nothing can host it anywhere -> terminal rejection
    vms = scenarios.uniform_vms(2, dc=0, ram_mb=512.0, storage_mb=1024.0,
                                request_t=np.array([0.0, 50.0]))
    cls = scenarios.make_cloudlets(np.array([0, 1]),
                                   np.array([100_000.0, 100_000.0]),
                                   np.zeros(2), input_mb=0.0, output_mb=0.0)
    out = workload.no_outages(1, 1, 1)
    out = out.replace(fail_t=out.fail_t.at[0, 0, 0].set(10.0),
                      repair_t=out.repair_t.at[0, 0, 0].set(100.0))
    scn = scenarios.Scenario(
        hosts=hosts, vms=vms, cloudlets=cls,
        market=scenarios.uniform_market(1),
        policy=scenarios.make_policy(core_reserving=True,
                                     ckpt_interval=INF, horizon=50_000.0),
        outages=out, max_steps=200)
    res = jax.jit(simulate)(scn)
    failed = np.array(res.vm_failed)
    assert not failed[0], "evicted VM must recover, not terminally fail"
    assert failed[1], "terminal creation rejection must survive the repair"
    fin = np.array(res.finish_t)
    assert fin[0] < 1e30, "recovered VM finishes its work"
    assert fin[1] >= 1e30, "doomed cloudlet never runs"


def test_mtbf_inf_matches_outage_free_program():
    """An all-INF schedule is bit-identical to detaching outages entirely."""
    scn = scenarios.reliability_scenario(None)
    res_ctrl = jax.jit(simulate)(scn)
    res_none = jax.jit(simulate)(scn.replace(outages=None, instruments=()))
    for f in dataclasses.fields(res_ctrl):
        np.testing.assert_array_equal(
            np.array(getattr(res_ctrl, f.name)),
            np.array(getattr(res_none, f.name)),
            err_msg=f"SimResult.{f.name} diverged")
    assert int(res_ctrl.n_evacuations) == 0
    assert float(res_ctrl.downtime) == 0.0


# ---------------------------------------------------------------------------
# proactive evacuation
# ---------------------------------------------------------------------------

def test_evacuation_beats_restart_from_zero():
    """The acceptance demo: federation + finite ckpt, evacuation on vs the
    restart-from-zero control — fewer violations, less downtime, same energy
    order of magnitude, work finished either way."""
    res_e = jax.jit(simulate)(scenarios.evacuation_scenario())
    res_c = jax.jit(simulate)(scenarios.evacuation_scenario(
        evacuation=False, ckpt_interval=INF))
    assert int(res_e.n_finished) == 2 and int(res_c.n_finished) == 2
    assert int(res_e.n_evacuations) == 2
    assert int(res_c.n_evacuations) == 0
    assert int(res_e.sla_violations) < int(res_c.sla_violations)
    assert float(res_e.downtime) < float(res_c.downtime)
    e_e = float(np.sum(np.array(res_e.energy_j)))
    e_c = float(np.sum(np.array(res_c.energy_j)))
    assert 0.1 < e_e / e_c < 10.0, "same energy order of magnitude"
    # progress preservation: alarm at 250, ~40.24s stop-and-copy, 600s work
    np.testing.assert_allclose(np.array(res_e.finish_t), 640.24, atol=0.5)
    # restart control: eviction at 300, transfer, full 600s again
    np.testing.assert_allclose(np.array(res_c.finish_t), 940.24, atol=0.5)


def test_evacuation_noop_without_federation():
    """The traced federation flag gates evacuation like every other
    coordinator policy: flipped off, the same program restarts from zero."""
    scn = scenarios.evacuation_scenario(ckpt_interval=INF)
    scn = scn.replace(policy=scn.policy.replace(
        federation=jnp.asarray(False)))
    res = jax.jit(simulate)(scn)
    assert int(res.n_evacuations) == 0
    assert int(res.n_migrations) == 0
    # no peer reachable: the work waits out the outage on the home host
    assert float(res.downtime) > 1000.0


# ---------------------------------------------------------------------------
# campaign surface: vmapped grid == Python loop
# ---------------------------------------------------------------------------

def test_vmapped_mtbf_policy_grid_matches_loop():
    """MTBF x (evacuation, ckpt) grid in one vmap row-matches per-scenario
    runs bitwise — revocation does not break the campaign contract."""
    template = scenarios.reliability_scenario(_mk())
    K = 6
    keys = jax.random.split(_mk(5), K)
    mtbfs = jnp.asarray(
        [300.0, 300.0, 900.0, 900.0, float(INF), float(INF)], jnp.float32)
    evac = jnp.asarray([True, False, True, False, True, False])
    ckpt = jnp.asarray(
        [25_000.0, float(INF)] * 3, jnp.float32)
    outs = jax.vmap(
        lambda k, m: workload.host_outages(k, 2, 3, 2, m, 300.0)
    )(keys, mtbfs)
    pols = jax.vmap(
        lambda e, c: template.policy.replace(evacuation=e, ckpt_interval=c)
    )(evac, ckpt)
    batched = broadcast_campaign(template, K, outages=outs, policy=pols)
    res_v = run_campaign(batched)

    checked = ("n_finished", "sla_violations", "downtime", "n_evacuations",
               "n_migrations", "makespan", "total_cost", "finish_t")
    for i in range(K):
        row = template.replace(
            policy=jax.tree.map(lambda x: x[i], pols),
            outages=jax.tree.map(lambda x: x[i], outs))
        res_i = jax.jit(simulate)(row)
        for f in checked:
            np.testing.assert_array_equal(
                np.array(getattr(res_v, f)[i]),
                np.array(getattr(res_i, f)),
                err_msg=f"row {i}: SimResult.{f} diverged from the loop")
    # the MTBF = INF rows are clean controls inside the same program
    assert int(np.array(res_v.n_evacuations)[4]) == 0
    assert float(np.array(res_v.downtime)[4]) == 0.0
