"""Data pipeline: determinism, host disjointness, learnable structure."""
import numpy as np

from repro.data import MarkovSource, ShardedLoader

import pytest

pytestmark = pytest.mark.tier1


def test_deterministic_stream():
    a = ShardedLoader(100, 4, 16, seed=5)
    b = ShardedLoader(100, 4, 16, seed=5)
    for _ in range(3):
        x, y = next(a), next(b)
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
        np.testing.assert_array_equal(x["labels"], y["labels"])
    a.close(); b.close()


def test_hosts_disjoint_batches():
    a = ShardedLoader(100, 8, 16, host_id=0, n_hosts=2, seed=5)
    b = ShardedLoader(100, 8, 16, host_id=1, n_hosts=2, seed=5)
    xa, xb = next(a), next(b)
    assert xa["tokens"].shape == (4, 16)
    assert not np.array_equal(xa["tokens"], xb["tokens"])
    a.close(); b.close()


def test_labels_are_shifted_tokens():
    l = ShardedLoader(50, 2, 10, seed=0)
    b = next(l)
    l.close()
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_markov_structure_learnable():
    """successor distribution concentrated (low entropy vs uniform)."""
    src = MarkovSource(64, seed=0)
    rng = np.random.default_rng(0)
    seq = src.sample(rng, 64, 128)
    # P(next in successor set) >> chance
    hits = 0
    total = 0
    for row in seq:
        for t in range(len(row) - 1):
            total += 1
            hits += row[t + 1] in src.succ[row[t]]
    assert hits / total > 0.8
