"""Auto-scaling subsystem: pool lifecycle, K_SCALE events, the acceptance
demo (bursty workload: autoscaled beats static fleet), and the 64-point
arrival-rate x threshold grid in one vmap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    broadcast_campaign,
    run_campaign,
    scenarios,
    simulate,
    simulate_history,
    simulate_instrumented,
    step,
    workload,
)

pytestmark = pytest.mark.tier1


def _autoscale_off(scn):
    return scn.replace(
        policy=scn.policy.replace(autoscale=jnp.asarray(False)))


def test_autoscale_improves_bursty_turnaround():
    """THE demo (ISSUE acceptance): under a bursty generated workload the
    autoscaled pool beats the same scenario with the pool disabled, all work
    finishing in both — and both runs are the same compiled program (the
    autoscale flag is traced, no Python branching on load)."""
    fn = jax.jit(simulate_instrumented)
    results = {}
    for name, scn in (
        ("on", scenarios.autoscale_scenario(jax.random.PRNGKey(0))),
        ("off", _autoscale_off(scenarios.autoscale_scenario(jax.random.PRNGKey(0)))),
    ):
        res, out = fn(scn)
        assert int(res.n_finished) == scn.cloudlets.n_cloudlets, name
        results[name] = (res, out)
    assert fn._cache_size() == 1, "on/off must share one compilation"
    res_on, out_on = results["on"]
    res_off, out_off = results["off"]
    assert int(out_on["autoscale"]["n_scale_up"]) > 0
    assert int(out_off["autoscale"]["n_scale_up"]) == 0
    assert float(res_on.mean_turnaround) < 0.9 * float(res_off.mean_turnaround)
    # the static fleet never touches the pool rows
    assert np.array(res_off.vm_placed).sum() == 4
    assert np.array(res_on.vm_placed).sum() == 8


def test_scale_up_lifecycle_and_boot_latency():
    """Activated pool VMs boot with the fixed creation latency before doing
    work: K_SCALE events appear in the history, and activations are gradual
    (one per DC per tick)."""
    scn = scenarios.autoscale_scenario(jax.random.PRNGKey(3))
    res, hist = jax.jit(simulate_history)(scn)
    v = np.array(hist.valid)
    kinds = np.array(hist.kind)[v]
    assert (kinds == step.K_SCALE).any(), "autoscaler ticks must be events"
    assert (kinds == step.K_COMPLETION).any()
    # scale tick period is respected: consecutive K_SCALE events >= interval
    ts = np.array(hist.t)[v][kinds == step.K_SCALE]
    assert (np.diff(ts) >= float(scn.policy.sensor_interval) - 1e-3).all()


def test_scale_down_releases_idle_pool():
    """With a scale-down threshold, pool VMs activated for burst 1 are
    released in the following lull (terminal: inactive -> activating ->
    active -> released), returning their host resources."""
    scn = scenarios.autoscale_scenario(
        jax.random.PRNGKey(1), scale_down_thresh=0.05)
    res, out = jax.jit(simulate_instrumented)(scn)
    assert int(out["autoscale"]["n_scale_up"]) > 0
    assert int(out["autoscale"]["n_scale_down"]) > 0
    assert int(res.n_finished) == scn.cloudlets.n_cloudlets


def test_pool_row_reactivates_across_bursts():
    """Pool rows are re-activatable (ROADMAP follow-up): with a single pool
    row and a scale-down threshold over a bursty trace, the same row must
    activate -> release -> re-activate (n_scale_up >= 2 with n_pool=1 can
    only mean the one row cycled the lifecycle), finishing all work."""
    scn = scenarios.autoscale_scenario(
        jax.random.PRNGKey(0), n_pool=1, scale_down_thresh=0.05)
    res, out = jax.jit(simulate_instrumented)(scn)
    assert int(out["autoscale"]["n_scale_up"]) >= 2
    assert int(out["autoscale"]["n_scale_down"]) >= 1
    assert int(res.n_finished) == scn.cloudlets.n_cloudlets
    # the recycled row ends the run placed again (its final activation)
    assert np.array(res.vm_placed).sum() == 5


def test_pool_invisible_without_autoscale():
    """A scenario whose pool is never activated is bit-identical to one with
    no pool rows at all: spare rows are dead weight, not a perturbation."""
    scn = _autoscale_off(scenarios.autoscale_scenario(jax.random.PRNGKey(5)))
    res = jax.jit(simulate)(scn)
    # same infra, but the pool hosts exist and stay empty: all 48 cloudlets
    # keep to the 4 base VMs
    vm_of = np.array(res.vm_placed)
    assert vm_of[:4].all() and not vm_of[4:].any()
    assert int(res.n_finished) == 48


def test_service_routing_balances_load():
    """Broker dispatch spreads arrivals across the active fleet instead of
    piling onto one VM: final assignments (SimResult.cl_vm) are balanced."""
    scn = _autoscale_off(scenarios.autoscale_scenario(jax.random.PRNGKey(2)))
    res = jax.jit(simulate)(scn)
    cl_vm = np.array(res.cl_vm)
    assert (cl_vm >= 0).all(), "every service row must have been dispatched"
    counts = np.bincount(cl_vm, minlength=8)
    assert (counts[:4] >= 6).all(), counts      # 48 rows over 4 base VMs
    assert not counts[4:].any()                 # pool never activated


def test_grid_campaign_64_points_one_vmap():
    """ISSUE acceptance: run_campaign sweeps an 8 arrival-rate x 8 threshold
    grid (64 scenarios: vmapped generated workloads + swept traced policy)
    in one vmap, every cell finishing all work."""
    template = scenarios.autoscale_scenario(jax.random.PRNGKey(0))
    K = 64
    rates = jnp.tile(jnp.linspace(0.05, 0.2, 8), 8)
    ups = jnp.repeat(jnp.linspace(0.3, 1.0, 8), 8)
    keys = jax.random.split(jax.random.PRNGKey(7), K)
    cls = jax.vmap(lambda k, r: workload.generate_cloudlets(
        k, 48, kind="bursty", n_bursts=3, rate=r, off_gap_mean=800.0,
        median_mi=60_000.0, sigma_mi=0.3, n_vms=None))(keys, rates)
    pol = jax.vmap(
        lambda u: template.policy.replace(scale_up_thresh=u))(ups)
    batched = broadcast_campaign(template, K, cloudlets=cls, policy=pol)
    res = run_campaign(batched)
    assert (np.array(res.n_finished) == 48).all()
    tat = np.array(res.mean_turnaround)
    assert np.isfinite(tat).all() and (tat > 0).all()
    # thresholds bite: the permissive half of the grid scales earlier and
    # beats the restrictive half on average over the same arrival rates
    lo = tat[np.array(ups) <= 0.6].mean()
    hi = tat[np.array(ups) > 0.6].mean()
    assert lo < hi


def test_broadcast_campaign_validates_leading_dim():
    template = scenarios.autoscale_scenario(jax.random.PRNGKey(0))
    cls = jax.vmap(lambda k: workload.generate_cloudlets(
        k, 48, kind="bursty", n_bursts=3, rate=0.1, n_vms=None)
    )(jax.random.split(jax.random.PRNGKey(1), 8))
    with pytest.raises(ValueError, match="leading dim"):
        broadcast_campaign(template, 16, cloudlets=cls)
