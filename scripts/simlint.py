#!/usr/bin/env python
"""Run simlint — the engine's structural-invariant verifier — from the CLI.

    PYTHONPATH=src python scripts/simlint.py               # human report
    PYTHONPATH=src python scripts/simlint.py --json out.json
    PYTHONPATH=src python scripts/simlint.py --rule R1 --rule R6
    PYTHONPATH=src python scripts/simlint.py --entry simulate --entry batch
    PYTHONPATH=src python scripts/simlint.py --list

Exit status: 0 when no error-severity findings, 1 when any rule errored,
2 on bad usage.  Warnings never fail the run (CI treats them as advisory).
"""
import argparse
import json
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="simlint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--rule", action="append", metavar="RN",
                    help="run only this rule (repeatable), e.g. --rule R2")
    ap.add_argument("--entry", action="append", metavar="NAME",
                    help="trace only this entry point (repeatable); rules "
                         "whose entries are all filtered out report nothing")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write findings as JSON ('-' for stdout)")
    ap.add_argument("--list", action="store_true",
                    help="list rules and entry points, then exit")
    args = ap.parse_args(argv)

    from repro.analysis import simlint

    if args.list:
        for rid in sorted(simlint.RULES):
            spec = simlint.RULES[rid]
            print(f"{rid}  {spec.name:20s} entries={','.join(spec.entries)}")
            print(f"    {spec.doc}")
        print("entry points:", ", ".join(simlint.ENTRY_NAMES))
        return 0

    try:
        findings = simlint.run_lint(rules=args.rule, entries=args.entry)
    except ValueError as e:
        print(f"simlint: {e}", file=sys.stderr)
        return 2

    print(simlint.format_report(findings, rules=args.rule))

    if args.json:
        payload = {
            "findings": [f.to_dict() for f in findings],
            "summary": simlint.summarize(findings),
            "rules_run": sorted(args.rule) if args.rule
            else sorted(simlint.RULES),
            "entries": list(args.entry) if args.entry
            else list(simlint.ENTRY_NAMES),
        }
        text = json.dumps(payload, indent=2)
        if args.json == "-":
            print(text)
        else:
            with open(args.json, "w") as fh:
                fh.write(text + "\n")

    return 1 if any(f.severity == "error" for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
