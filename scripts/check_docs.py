#!/usr/bin/env python
"""Docs-drift gate: docs/API.md field tables must match the live dataclasses.

    PYTHONPATH=src python scripts/check_docs.py

API.md documents ``Hosts``, ``Policy``, ``Cloudlets`` and ``SimResult`` as
markdown tables whose first column is the backtick-quoted field name.  Adding a dataclass field without
documenting it — or documenting a field that no longer exists — is exactly
the silent drift that makes hand-written API docs rot, so CI fails on any
asymmetric difference.  Field sets are compared, not order or prose.

Code examples in the docs are verified separately (executed) by
tests/test_docs.py; this script only audits the declarative tables.

Exit status: 0 in sync, 1 drift, 2 missing/unparseable docs.
"""
from __future__ import annotations

import dataclasses
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
API_MD = os.path.join(ROOT, "docs", "API.md")

# (heading regex locating the table, dataclass path)
TABLES = (
    (r"##.*\bHosts fields\b", "repro.core.entities:Hosts"),
    (r"##.*\bPolicy fields\b", "repro.core.entities:Policy"),
    (r"##.*\bCloudlets fields\b", "repro.core.entities:Cloudlets"),
    (r"##.*\bSimResult fields\b", "repro.core.entities:SimResult"),
)

_ROW_FIELD = re.compile(r"^\|\s*`([A-Za-z_][A-Za-z0-9_]*)`")


def table_fields(text: str, heading_re: str) -> set[str] | None:
    """Backtick-quoted first-column names of the first markdown table under
    the heading, or None if heading/table is missing."""
    m = re.search(heading_re, text)
    if not m:
        return None
    fields: set[str] = set()
    in_table = False
    for line in text[m.end():].splitlines():
        row = _ROW_FIELD.match(line.strip())
        if row:
            in_table = True
            fields.add(row.group(1))
        elif in_table and not line.strip().startswith("|"):
            break
    return fields or None


def live_fields(spec: str) -> set[str]:
    mod_name, cls_name = spec.split(":")
    mod = __import__(mod_name, fromlist=[cls_name])
    return {f.name for f in dataclasses.fields(getattr(mod, cls_name))}


def main() -> int:
    try:
        with open(API_MD) as f:
            text = f.read()
    except OSError as e:
        print(f"error: cannot read {API_MD}: {e}", file=sys.stderr)
        return 2
    status = 0
    for heading_re, spec in TABLES:
        documented = table_fields(text, heading_re)
        name = spec.split(":")[1]
        if documented is None:
            print(f"error: no field table under /{heading_re}/ in API.md",
                  file=sys.stderr)
            status = max(status, 2)
            continue
        live = live_fields(spec)
        missing = sorted(live - documented)
        stale = sorted(documented - live)
        if missing:
            print(f"DRIFT {name}: undocumented fields {missing}",
                  file=sys.stderr)
        if stale:
            print(f"DRIFT {name}: documented but gone {stale}",
                  file=sys.stderr)
        if missing or stale:
            status = max(status, 1)
        else:
            print(f"ok {name}: {len(live)} fields in sync")
    return status


if __name__ == "__main__":
    sys.exit(main())
