import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import run_cell, RESULTS_DIR
from repro.configs import ARCH_IDS
from repro.models import ALL_SHAPES
from repro.models.config import TRAIN_4K, DECODE_32K

os.makedirs(RESULTS_DIR, exist_ok=True)

def save(out, name):
    json.dump(out, open(os.path.join(RESULTS_DIR, name + ".json"), "w"), indent=2)
    r = out.get("roofline")
    if r:
        print("%s: comp=%.0fms coll=%.0fms resid=%.2fGB bound=%.1f%%" % (
            name, 1e3*r["compute_s"], 1e3*r["collective_s"],
            out["memory_model"]["residency_bytes"]/1e9,
            100*r["roofline_fraction"]), flush=True)
    else:
        print(name, "->", out.get("skipped", out.get("error", "?"))[:80], flush=True)

# baselines, both meshes
for mesh in ("single", "multi"):
    for arch in ARCH_IDS:
        for shape in ALL_SHAPES:
            try:
                out = run_cell(arch, shape, mesh)
            except Exception as e:
                import traceback
                out = {"arch": arch, "shape": shape.name, "mesh": mesh,
                       "error": traceback.format_exc()}
            save(out, f"{arch}__{shape.name}__{mesh}")

# hillclimb variants
variants = [
    ("gemma2-27b", TRAIN_4K, dict(microbatches=1, sequence_parallel=True), "opt1_sp_mb1"),
    ("gemma2-27b", TRAIN_4K, dict(microbatches=1, strategy="fsdp"), "opt2_fsdp_mb1"),
    ("gemma2-27b", TRAIN_4K, dict(microbatches=1, strategy="fsdp", master_bf16=True), "opt3_fsdp_mb1_bf16"),
    ("qwen3-moe-235b-a22b", TRAIN_4K, dict(microbatches=1, sequence_parallel=True, master_bf16=True), "opt1_sp_mb1_bf16"),
    ("qwen3-moe-235b-a22b", TRAIN_4K, dict(microbatches=4, master_bf16=True,
                                           extra_cfg=dict(remat_policy="save_named")), "opt2_bf16_rematpol"),
    ("qwen3-32b", DECODE_32K, dict(), "opt1_flashdecode"),
]
for arch, shape, kw, tag in variants:
    try:
        out = run_cell(arch, shape, "single", tag=tag, **kw)
    except Exception:
        import traceback
        out = {"arch": arch, "shape": shape.name, "error": traceback.format_exc()}
    save(out, f"{arch}__{shape.name}__single__{tag}")
